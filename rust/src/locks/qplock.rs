//! **qplock** — the paper's asymmetric mutual exclusion primitive
//! (Algorithms 1 and 2).
//!
//! Two *budgeted MCS queue cohort locks* — one for the lock's local
//! processes, one for remote processes — are embedded in a *modified
//! Peterson lock*: a process first competes inside its cohort's queue;
//! the queue's leader (the process that found the queue empty) then runs
//! the two-party Peterson protocol against the other cohort's leader.
//! "Cohort lock is held" doubles as the Peterson flag (`cohort[id] ≠
//! null`), which is what lets the MCS tail word *be* the announcement —
//! saving the extra remote write a layered cohorting design would pay.
//!
//! Properties delivered (and asserted by tests/experiments):
//!
//! * **Local processes never issue an RDMA operation** — every register
//!   they touch (victim, both tail words, their own and other local
//!   descriptors) lives on the home node.
//! * **Remote processes need O(1) remote verbs per acquisition** — one
//!   rCAS when the queue is empty (plus the Peterson engagement: one
//!   rWrite + rReads while the other cohort holds), or one rCAS + one
//!   rWrite to enqueue, after which they spin on *their own node's*
//!   memory until the budget word is written by their predecessor.
//! * **Starvation freedom & FCFS fairness** — the MCS queues are FIFO;
//!   the `budget` bounds consecutive intra-cohort handoffs, after which
//!   the holder must `pReacquire` the Peterson lock, yielding to a
//!   waiting opposite-class leader (paper §3.1, after Dice et al.'s lock
//!   cohorting).
//!
//! Register/descriptor layout:
//!
//! ```text
//! home node:   victim | tail[LOCAL] | tail[REMOTE]      (1 word each)
//! each proc:   desc = [ budget | next ]                 (on its own node)
//! ```
//!
//! `budget = u64::MAX` encodes the paper's −1 ("enqueued, not passed").

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Arc;

use super::{Class, LockHandle, SharedLock};
use crate::rdma::{Addr, Endpoint, NodeId, RdmaDomain};
use crate::util::spin::Backoff;

/// The paper's −1 sentinel for "waiting" in the budget word.
const WAITING: u64 = u64::MAX;

/// Offset of the `next` field inside a descriptor.
const NEXT: u32 = 1;

/// The one shared identity of a qplock: the three home-node registers,
/// the configured `kInitBudget`, and host-side per-lock state. Held by
/// [`Arc`] from both [`QpLock`] and every [`QpHandle`], so all handles
/// of one lock observe the *same* object — per-lock counters (and any
/// future shared state: lease words, async wakeup lists) stay coherent
/// no matter which path minted the handle.
pub struct QpInner {
    victim: Addr,
    tail: [Addr; 2],
    home: NodeId,
    init_budget: u64,
    /// Host-side accounting (not an RDMA register): acquisitions that
    /// found their cohort queue non-empty. Relaxed — off the protocol's
    /// critical decisions, like `ProcMetrics`.
    contended: AtomicU64,
    /// Handles minted over this lock's lifetime.
    handles_minted: AtomicU64,
}

/// Shared side of a qplock: three registers on the home node plus the
/// configured initial budget (`kInitBudget`).
pub struct QpLock {
    inner: Arc<QpInner>,
}

impl QpLock {
    /// Allocate the lock's registers on `home`. `init_budget ≥ 1` is the
    /// paper's `kInitBudget`: the number of consecutive intra-cohort
    /// handoffs before the holder must re-acquire the global lock.
    pub fn create(domain: &Arc<RdmaDomain>, home: NodeId, init_budget: u64) -> Arc<QpLock> {
        assert!(init_budget >= 1, "kInitBudget must be positive");
        assert!(
            init_budget < WAITING,
            "budget must be distinguishable from the WAITING sentinel"
        );
        let mem = &domain.node(home).mem;
        Arc::new(QpLock {
            inner: Arc::new(QpInner {
                victim: mem.alloc(1),
                tail: [mem.alloc(1), mem.alloc(1)],
                home,
                init_budget,
                contended: AtomicU64::new(0),
                handles_minted: AtomicU64::new(0),
            }),
        })
    }

    pub fn init_budget(&self) -> u64 {
        self.inner.init_budget
    }

    /// Acquisitions (across *all* handles of this lock) that enqueued
    /// behind a cohort predecessor — a contention signal for placement/
    /// rebalancing decisions at the service layer.
    pub fn contended_acquisitions(&self) -> u64 {
        self.inner.contended.load(Relaxed)
    }

    /// Handles minted over this lock's lifetime, via either
    /// [`QpLock::qp_handle`] or the object-safe [`SharedLock::handle`].
    pub fn handles_minted(&self) -> u64 {
        self.inner.handles_minted.load(Relaxed)
    }

    /// Mint a handle; locality class is derived from the endpoint's node.
    pub fn qp_handle(&self, ep: Endpoint) -> QpHandle {
        self.inner.mint(ep)
    }
}

impl QpInner {
    fn mint(self: &Arc<Self>, ep: Endpoint) -> QpHandle {
        self.handles_minted.fetch_add(1, Relaxed);
        let class = Class::of(&ep, self.home);
        let desc = ep.alloc(2); // budget, next — always on the caller's node
        QpHandle {
            shared: Arc::clone(self),
            ep,
            class,
            desc,
        }
    }
}

impl SharedLock for QpLock {
    fn handle(&self, ep: Endpoint, _pid: u32) -> Box<dyn LockHandle> {
        // `SharedLock` is object-safe so this can't take `self:
        // &Arc<Self>` — but the shared identity lives one level down in
        // `self.inner`, which *is* an `Arc` we can clone. Every handle
        // therefore shares the original `QpInner` (registers and
        // counters), instead of the old bug of reconstructing a fresh
        // lock object per handle.
        Box::new(self.inner.mint(ep))
    }

    fn name(&self) -> &'static str {
        "qplock"
    }

    fn home(&self) -> NodeId {
        self.inner.home
    }
}

/// Per-process handle: endpoint, locality class, and the process's MCS
/// descriptor (resident on the process's own node, so every wait in the
/// cohort layer is a local spin). Shares the lock's [`QpInner`].
pub struct QpHandle {
    shared: Arc<QpInner>,
    ep: Endpoint,
    class: Class,
    desc: Addr,
}

impl QpHandle {
    pub fn class(&self) -> Class {
        self.class
    }

    pub fn endpoint(&self) -> &Endpoint {
        &self.ep
    }

    // ---- class-dispatched access to home-node registers ----
    //
    // A Local-class process co-resides with victim/tail and uses CPU
    // accesses; a Remote-class process must use verbs. This dispatch *is*
    // the paper's operation-asymmetry discipline.

    #[inline]
    fn home_read(&self, a: Addr) -> u64 {
        match self.class {
            Class::Local => self.ep.read(a),
            Class::Remote => self.ep.r_read(a),
        }
    }

    #[inline]
    fn home_write(&self, a: Addr, v: u64) {
        match self.class {
            Class::Local => self.ep.write(a, v),
            Class::Remote => self.ep.r_write(a, v),
        }
    }

    #[inline]
    fn home_cas(&self, a: Addr, expected: u64, swap: u64) -> u64 {
        match self.class {
            Class::Local => self.ep.cas(a, expected, swap),
            Class::Remote => self.ep.r_cas(a, expected, swap),
        }
    }

    /// Write a field of *another* process's descriptor. For a local-class
    /// process every cohort member is on the home node (local write); a
    /// remote-class process reaches its predecessor/successor with rWrite
    /// (paper Algorithm 2 lines 9 and 19).
    #[inline]
    fn peer_write(&self, a: Addr, v: u64) {
        match self.class {
            Class::Local => self.ep.write(a, v),
            Class::Remote => self.ep.r_write(a, v),
        }
    }

    // ---- budgeted MCS cohort lock (paper Algorithm 2) ----

    /// `qLock()`: enqueue into this class's cohort queue. Returns `true`
    /// iff the queue was empty — the caller is the cohort *leader* and
    /// must engage the Peterson protocol; `false` means the Peterson lock
    /// was handed over inside the cohort.
    fn q_lock(&mut self) -> bool {
        let tail = self.shared.tail[self.class.idx()];
        // Descriptor init (local writes: desc is ours). Perf note
        // (EXPERIMENTS.md §Perf): the budget word is written *after* the
        // tail swap decides our role — the leader keeps kInit, a waiter
        // needs WAITING — saving one store on every acquisition vs. the
        // paper's "init both fields first" presentation. Safe because a
        // predecessor can only touch our budget after we link (line 9),
        // which happens after the WAITING store below. `next` must be
        // null *before* the swap: a successor may link the instant the
        // tail CAS lands.
        self.ep.write_desc(self.desc.offset(NEXT), 0);
        // Swap ourselves in as the new tail (CAS loop, curr updated on
        // failure — Algorithm 2 line 4).
        let mut curr = 0u64;
        loop {
            let seen = self.home_cas(tail, curr, self.desc.to_bits());
            if seen == curr {
                break;
            }
            curr = seen;
        }
        if curr == 0 {
            // Queue was empty: we are the leader; set budget = kInit.
            self.ep.write_desc(self.desc, self.shared.init_budget);
            return true;
        }
        // Enqueue behind `curr`: mark ourselves waiting *before* linking,
        // so the predecessor cannot pass the lock before we are ready.
        self.shared.contended.fetch_add(1, Relaxed);
        self.ep.write_desc(self.desc, WAITING);
        self.peer_write(Addr::from_bits(curr).offset(NEXT), self.desc.to_bits());
        // Busy-wait locally on our own budget word (Algorithm 2 line 10),
        // remembering the handed-over value (saves a re-read on exit).
        let mut bo = Backoff::default();
        let mut budget;
        loop {
            budget = self.ep.read_desc(self.desc);
            if budget != WAITING {
                break;
            }
            bo.snooze();
        }
        // Budget exhausted: yield the global lock to the other class and
        // re-acquire it (fairness — Algorithm 2 lines 11-13).
        if budget == 0 {
            self.p_reacquire();
            self.ep.write_desc(self.desc, self.shared.init_budget);
        }
        false
    }

    /// `qUnlock()`: release the cohort lock — either reset the tail (also
    /// releasing the Peterson lock, since `cohort[id]` becomes null) or
    /// pass to the successor with a decremented budget.
    fn q_unlock(&mut self) {
        let tail = self.shared.tail[self.class.idx()];
        if self.ep.read_desc(self.desc.offset(NEXT)) == 0 {
            if self.home_cas(tail, self.desc.to_bits(), 0) == self.desc.to_bits() {
                return;
            }
            // A successor is between its tail-CAS and its link write;
            // wait for the link (local spin on our own next field).
            let mut bo = Backoff::default();
            while self.ep.read_desc(self.desc.offset(NEXT)) == 0 {
                bo.snooze();
            }
        }
        let next = Addr::from_bits(self.ep.read_desc(self.desc.offset(NEXT)));
        let budget = self.ep.read_desc(self.desc);
        debug_assert!(budget >= 1 && budget != WAITING);
        self.peer_write(next, budget - 1); // pass the lock
    }

    /// `qIsLocked()` on the *other* cohort: its tail register is non-null.
    #[inline]
    fn other_cohort_locked(&self) -> bool {
        self.home_read(self.shared.tail[1 - self.class.idx()]) != 0
    }

    // ---- modified Peterson lock (paper Algorithm 1) ----

    /// Global-lock engagement for a cohort leader: set ourselves as the
    /// victim, then wait until the other cohort is unlocked or yields.
    fn p_engage(&mut self) {
        let me = self.class.idx() as u64;
        self.home_write(self.shared.victim, me);
        let mut bo = Backoff::default();
        while self.other_cohort_locked() && self.home_read(self.shared.victim) == me {
            bo.snooze();
        }
    }

    /// `pReacquire()` (Algorithm 1 line 12): release-and-reacquire the
    /// global lock — yields to a waiting opposite-class leader, then
    /// takes the lock back. Called on budget exhaustion.
    fn p_reacquire(&mut self) {
        self.p_engage();
    }
}

impl LockHandle for QpHandle {
    /// `pLock()` (Algorithm 1): cohort first; leaders engage Peterson.
    fn lock(&mut self) {
        let is_leader = self.q_lock();
        if is_leader {
            self.p_engage();
        }
    }

    /// `pUnlock()` (Algorithm 1): release the cohort lock; releasing the
    /// tail releases the Peterson flag implicitly.
    fn unlock(&mut self) {
        self.q_unlock();
    }

    fn algorithm(&self) -> &'static str {
        "qplock"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::locks::CsChecker;
    use crate::rdma::{DomainConfig, RdmaDomain};

    fn stress(
        lock: &Arc<QpLock>,
        d: &Arc<RdmaDomain>,
        procs: &[(u16, u32)],
        iters: u64,
    ) -> Arc<CsChecker> {
        let check = CsChecker::new();
        let mut ts = vec![];
        for &(node, pid) in procs {
            let mut h = lock.qp_handle(d.endpoint(node));
            let c = Arc::clone(&check);
            ts.push(std::thread::spawn(move || {
                for _ in 0..iters {
                    h.lock();
                    c.enter(pid);
                    c.exit(pid);
                    h.unlock();
                }
            }));
        }
        for t in ts {
            t.join().unwrap();
        }
        check
    }

    #[test]
    fn lone_local_process_issues_zero_rdma_ops() {
        let d = RdmaDomain::new(2, 1024, DomainConfig::counted());
        let l = QpLock::create(&d, 0, 8);
        let mut h = l.qp_handle(d.endpoint(0));
        for _ in 0..100 {
            h.lock();
            h.unlock();
        }
        let s = h.ep.metrics.snapshot();
        assert_eq!(s.remote_total(), 0, "local class must never touch the NIC");
        assert_eq!(s.loopback, 0);
        assert!(s.local_total() > 0);
    }

    #[test]
    fn lone_remote_process_uses_single_rcas_for_cohort() {
        let d = RdmaDomain::new(2, 1024, DomainConfig::counted());
        let l = QpLock::create(&d, 0, 8);
        let mut h = l.qp_handle(d.endpoint(1));
        let before = h.ep.metrics.snapshot();
        h.lock();
        let acq = h.ep.metrics.snapshot() - before;
        // Cohort: exactly 1 rCAS (empty queue). Peterson engagement: one
        // rWrite (victim) + one rRead (other tail, unlocked on first
        // check). Nothing else.
        assert_eq!(acq.remote_cas, 1, "paper: lone process needs a single rCAS");
        assert_eq!(acq.remote_write, 1);
        assert_eq!(acq.remote_read, 1);
        let before = h.ep.metrics.snapshot();
        h.unlock();
        let rel = h.ep.metrics.snapshot() - before;
        // Unlock, no successor: 1 rCAS to clear the tail.
        assert_eq!(rel.remote_cas, 1);
        assert_eq!(rel.remote_write, 0);
        // All waiting/descriptor work is local to the process's node.
        assert_eq!(acq.loopback + rel.loopback, 0);
    }

    #[test]
    fn two_local_processes_mutual_exclusion() {
        let d = RdmaDomain::new(1, 4096, DomainConfig::counted());
        let l = QpLock::create(&d, 0, 4);
        let c = stress(&l, &d, &[(0, 1), (0, 2)], 3_000);
        assert_eq!(c.violations(), 0);
        assert_eq!(c.entries(), 6_000);
    }

    #[test]
    fn local_vs_remote_mutual_exclusion() {
        let d = RdmaDomain::new(2, 4096, DomainConfig::counted());
        let l = QpLock::create(&d, 0, 4);
        let c = stress(&l, &d, &[(0, 1), (1, 2)], 3_000);
        assert_eq!(c.violations(), 0);
        assert_eq!(c.entries(), 6_000);
    }

    #[test]
    fn many_mixed_processes_mutual_exclusion() {
        let d = RdmaDomain::new(3, 8192, DomainConfig::counted());
        let l = QpLock::create(&d, 0, 3);
        let procs: Vec<(u16, u32)> = (0..9u32).map(|i| ((i % 3) as u16, i + 1)).collect();
        let c = stress(&l, &d, &procs, 500);
        assert_eq!(c.violations(), 0);
        assert_eq!(c.entries(), 9 * 500);
    }

    #[test]
    fn local_class_never_issues_rdma_even_under_contention() {
        let d = RdmaDomain::new(2, 8192, DomainConfig::counted());
        let l = QpLock::create(&d, 0, 2);
        let check = CsChecker::new();
        let mut ts = vec![];
        let mut local_eps = vec![];
        for pid in 1..=4u32 {
            let node = if pid <= 2 { 0u16 } else { 1 };
            let ep = d.endpoint(node);
            if node == 0 {
                local_eps.push(Arc::clone(&ep.metrics));
            }
            let mut h = l.qp_handle(ep);
            let c = Arc::clone(&check);
            ts.push(std::thread::spawn(move || {
                for _ in 0..1_000 {
                    h.lock();
                    c.enter(pid);
                    c.exit(pid);
                    h.unlock();
                }
            }));
        }
        for t in ts {
            t.join().unwrap();
        }
        assert_eq!(check.violations(), 0);
        for m in local_eps {
            let s = m.snapshot();
            assert_eq!(s.remote_total(), 0);
            assert_eq!(s.loopback, 0);
        }
    }

    #[test]
    fn remote_waiters_spin_locally_not_remotely() {
        // Two remote processes on different nodes: the queued one must
        // wait by reading its own node's memory, not by hammering the
        // home node. We check that rRead count stays O(1) per acquisition
        // even though waiting involves thousands of spin iterations.
        let d = RdmaDomain::new(3, 4096, DomainConfig::counted());
        let l = QpLock::create(&d, 0, 8);
        let check = CsChecker::new();
        let mut ts = vec![];
        let mut metrics = vec![];
        for (node, pid) in [(1u16, 1u32), (2, 2)] {
            let ep = d.endpoint(node);
            metrics.push(Arc::clone(&ep.metrics));
            let mut h = l.qp_handle(ep);
            let c = Arc::clone(&check);
            ts.push(std::thread::spawn(move || {
                for _ in 0..2_000 {
                    h.lock();
                    c.enter(pid);
                    c.exit(pid);
                    h.unlock();
                }
            }));
        }
        for t in ts {
            t.join().unwrap();
        }
        assert_eq!(check.violations(), 0);
        for m in metrics {
            let s = m.snapshot();
            let per_acq = s.remote_total() as f64 / 2_000.0;
            // 1 rCAS + ≤1 rWrite on acquire, ≤ rCAS+rWrite on release,
            // + Peterson engagement rWrite/rReads on leader path. Budget
            // 8 means ~1/8 of acquisitions run pReacquire. Anything
            // remotely like remote spinning would blow past this bound.
            assert!(
                per_acq < 12.0,
                "remote ops per acquisition too high: {per_acq}"
            );
        }
    }

    #[test]
    fn budget_bounds_intra_cohort_handoffs() {
        // With budget B, a cohort of spinning waiters must re-engage the
        // global lock every B handoffs; we can't observe pReacquire
        // directly, but we can check a long same-class run completes and
        // the victim word was written more than once (each engagement
        // writes it).
        let d = RdmaDomain::new(2, 4096, DomainConfig::counted());
        let l = QpLock::create(&d, 0, 2);
        let c = stress(&l, &d, &[(1, 1), (1, 2), (1, 3)], 400);
        assert_eq!(c.violations(), 0);
        assert_eq!(c.entries(), 1_200);
    }

    #[test]
    fn works_under_global_atomicity_too() {
        use crate::rdma::AtomicityMode;
        let d = RdmaDomain::new(
            2,
            4096,
            DomainConfig::counted().with_atomicity(AtomicityMode::Global),
        );
        let l = QpLock::create(&d, 0, 4);
        let c = stress(&l, &d, &[(0, 1), (1, 2), (0, 3), (1, 4)], 800);
        assert_eq!(c.violations(), 0);
    }

    #[test]
    #[should_panic(expected = "kInitBudget must be positive")]
    fn zero_budget_rejected() {
        let d = RdmaDomain::new(1, 256, DomainConfig::counted());
        let _ = QpLock::create(&d, 0, 0);
    }

    #[test]
    fn handles_share_one_inner_identity() {
        // The old `SharedLock::handle` rebuilt a fresh Arc<QpLock> per
        // handle: register addresses happened to match, but per-lock
        // host state diverged. Now every handle holds the original
        // QpInner — counters accumulate across mint paths.
        use crate::locks::SharedLock;
        let d = RdmaDomain::new(2, 4096, DomainConfig::counted());
        let l = QpLock::create(&d, 0, 4);
        assert_eq!(l.handles_minted(), 0);
        let dyn_lock: &dyn SharedLock = l.as_ref();
        let mut a = dyn_lock.handle(d.endpoint(0), 1);
        let b = dyn_lock.handle(d.endpoint(0), 2);
        let h3 = l.qp_handle(d.endpoint(1));
        assert!(Arc::ptr_eq(&h3.shared, &l.inner), "same inner identity");
        assert_eq!(l.handles_minted(), 3);
        // Contention observed through dyn-minted handles lands on the
        // lock object's own counter: hold via `a`, enqueue `b` behind
        // it, and watch the shared counter tick (the old fresh-Arc
        // reconstruction would have ticked a private copy instead).
        a.lock();
        let t = std::thread::spawn(move || {
            let mut b = b;
            b.lock();
            b.unlock();
        });
        while l.contended_acquisitions() == 0 {
            std::thread::yield_now();
        }
        a.unlock();
        t.join().unwrap();
        assert_eq!(l.contended_acquisitions(), 1);
    }
}
