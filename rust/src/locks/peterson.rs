//! Standalone two-party Peterson lock over RDMA registers.
//!
//! This is the *global* layer of the paper's construction in isolation:
//! Peterson's algorithm (Peterson, IPL 1981) works over plain read-write
//! registers, which — unlike RMW operations — **are** atomic between
//! local and remote accesses at 8-byte granularity (paper Table 1). That
//! is precisely why the paper reaches for Peterson: it is the classic
//! starvation-free two-process lock built from the "greatest common
//! denominator" of the asymmetric operation sets.
//!
//! One party is the lock's local side (class 0, local ops only), the
//! other its remote side (class 1, remote verbs only). The embedded
//! version inside [`super::qplock`] replaces the boolean `flag` registers
//! with "cohort tail ≠ null" (see paper Algorithm 1); this standalone
//! variant keeps explicit flags and exists for unit testing the global
//! protocol and for pedagogy (`examples/quickstart.rs` uses it too).

use std::sync::Arc;

use super::{Class, LockHandle};
use crate::rdma::{Addr, Endpoint, NodeId, RdmaDomain};
use crate::util::spin::Backoff;

/// Shared registers of a two-party Peterson lock (all on the home node).
pub struct PetersonPair {
    flag: [Addr; 2],
    victim: Addr,
    home: NodeId,
}

impl PetersonPair {
    /// Allocate the three registers on `home`.
    pub fn create(domain: &Arc<RdmaDomain>, home: NodeId) -> Arc<PetersonPair> {
        let mem = &domain.node(home).mem;
        Arc::new(PetersonPair {
            flag: [mem.alloc(1), mem.alloc(1)],
            victim: mem.alloc(1),
            home,
        })
    }

    pub fn home(&self) -> NodeId {
        self.home
    }

    /// Handle for one party. Exactly one process per class may use the
    /// pair at a time (it is a two-process lock; qplock's cohort layer is
    /// what generalizes it).
    pub fn handle(self: &Arc<Self>, ep: Endpoint) -> PetersonHandle {
        let class = Class::of(&ep, self.home);
        PetersonHandle {
            shared: Arc::clone(self),
            ep,
            class,
        }
    }
}

/// One party's handle. Class decides local vs remote verbs for every
/// access — a local party never touches the NIC.
pub struct PetersonHandle {
    shared: Arc<PetersonPair>,
    ep: Endpoint,
    class: Class,
}

impl PetersonHandle {
    #[inline]
    fn rd(&self, a: Addr) -> u64 {
        match self.class {
            Class::Local => self.ep.read(a),
            Class::Remote => self.ep.r_read(a),
        }
    }

    #[inline]
    fn wr(&self, a: Addr, v: u64) {
        match self.class {
            Class::Local => self.ep.write(a, v),
            Class::Remote => self.ep.r_write(a, v),
        }
    }

    pub fn class(&self) -> Class {
        self.class
    }
}

impl LockHandle for PetersonHandle {
    fn lock(&mut self) {
        let me = self.class.idx();
        let other = 1 - me;
        self.wr(self.shared.flag[me], 1);
        self.wr(self.shared.victim, me as u64);
        let mut bo = Backoff::default();
        while self.rd(self.shared.flag[other]) == 1
            && self.rd(self.shared.victim) == me as u64
        {
            bo.snooze();
        }
    }

    fn unlock(&mut self) {
        let me = self.class.idx();
        self.wr(self.shared.flag[me], 0);
    }

    fn algorithm(&self) -> &'static str {
        "peterson-2p"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::locks::CsChecker;
    use crate::rdma::{DomainConfig, RdmaDomain};

    #[test]
    fn uncontended_local_party_uses_no_rdma() {
        let d = RdmaDomain::new(2, 256, DomainConfig::counted());
        let p = PetersonPair::create(&d, 0);
        let mut h = p.handle(d.endpoint(0));
        for _ in 0..10 {
            h.lock();
            h.unlock();
        }
        assert_eq!(h.ep.metrics.snapshot().remote_total(), 0);
    }

    #[test]
    fn uncontended_remote_party_uses_only_rdma() {
        let d = RdmaDomain::new(2, 256, DomainConfig::counted());
        let p = PetersonPair::create(&d, 0);
        let mut h = p.handle(d.endpoint(1));
        h.lock();
        h.unlock();
        let s = h.ep.metrics.snapshot();
        assert_eq!(s.local_total(), 0);
        // flag=1, victim, read other flag (exit), flag=0.
        assert_eq!(s.remote_write, 3);
        assert!(s.remote_read >= 1);
    }

    #[test]
    fn two_parties_mutual_exclusion_stress() {
        let d = RdmaDomain::new(2, 256, DomainConfig::counted());
        let p = PetersonPair::create(&d, 0);
        let check = CsChecker::new();
        let mut threads = vec![];
        for (node, pid) in [(0u16, 1u32), (1, 2)] {
            let mut h = p.handle(d.endpoint(node));
            let c = Arc::clone(&check);
            threads.push(std::thread::spawn(move || {
                for _ in 0..2_000 {
                    h.lock();
                    c.enter(pid);
                    c.exit(pid);
                    h.unlock();
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(check.violations(), 0);
        assert_eq!(check.entries(), 4_000);
    }
}
