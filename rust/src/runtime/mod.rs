//! PJRT runtime bridge (system S12): load AOT HLO-text artifacts and
//! execute them from the Rust hot path. Python never runs here.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Artifacts are produced once by `make artifacts`
//! (`python/compile/aot.py`); each compiled executable is wrapped in an
//! [`XlaEngine`] and reused for every request.

pub mod param_server;

use std::path::Path;

use anyhow::{Context, Result};

pub use param_server::ParamServer;

/// A PJRT client plus the executables loaded into it. One per process.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    /// CPU PJRT client (the plugin the `xla` crate ships against).
    pub fn cpu() -> Result<XlaRuntime> {
        Ok(XlaRuntime {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<XlaEngine> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(XlaEngine {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// One compiled XLA executable (one model entry point).
pub struct XlaEngine {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl XlaEngine {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with f32 tensor inputs (`(data, dims)` pairs); returns the
    /// output tuple's parts as flat f32 vectors. The artifacts are lowered
    /// with `return_tuple=True`, so the single output is always a tuple.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                if dims.len() == 1 {
                    Ok(lit)
                } else {
                    lit.reshape(dims).context("reshaping input literal")
                }
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("executing XLA computation")?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = result.to_tuple().context("decomposing result tuple")?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // Engine tests that need artifacts live in
    // rust/tests/runtime_integration.rs (artifacts are built by `make
    // artifacts`, not by cargo). Here: client creation only.
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = XlaRuntime::cpu().expect("PJRT CPU client");
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn loading_missing_artifact_fails_cleanly() {
        let rt = XlaRuntime::cpu().unwrap();
        let err = rt.load("/nonexistent/file.hlo.txt");
        assert!(err.is_err());
    }
}
