//! Compute runtime for critical-section payloads (system S12).
//!
//! The original design executed AOT-compiled JAX/Pallas artifacts
//! through a PJRT client (`xla` crate). That crate is not in the
//! vendored registry — the build environment is offline — so this
//! module ships a **native execution engine** instead: the exact math
//! of `python/compile/kernels/ref.py` (`S' = decay·S + lr·U·Vᵀ`,
//! `metric = mean(S'²)`, `Y = S·X`) implemented in Rust and
//! cross-validated against the JAX oracles by the Python test suite.
//! This is the same hardware-substitution discipline the RDMA layer
//! uses (DESIGN.md §Hardware-substitution): preserve the semantics the
//! experiments depend on, document what real hardware/software would
//! differ.
//!
//! The PJRT path can be restored behind this same API once an `xla`
//! crate is vendored; nothing outside this module names PJRT types.

pub mod param_server;

pub use param_server::ParamServer;

/// Dimensions and constants of the compiled model (mirrors the
/// `python/compile/aot.py` defaults, recorded in its manifest).
#[derive(Clone, Copy, Debug)]
pub struct ParamShape {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub c: usize,
    pub decay: f32,
    pub lr: f32,
}

impl Default for ParamShape {
    fn default() -> Self {
        // aot.py defaults.
        ParamShape {
            m: 256,
            n: 256,
            k: 8,
            c: 4,
            decay: 0.99,
            lr: 0.05,
        }
    }
}

/// Runtime error type (the vendored registry has no `anyhow`; a string
/// wrapper is all the layer needs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "runtime: {}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// The process-wide compute runtime. With the PJRT plugin unavailable
/// this is a handle to the native engine; it keeps the constructor
/// shape (`cpu()` can fail) so the PJRT backend can slot back in.
pub struct XlaRuntime {
    platform: &'static str,
}

impl XlaRuntime {
    /// Bring up the CPU engine.
    pub fn cpu() -> Result<XlaRuntime> {
        Ok(XlaRuntime {
            platform: "native-cpu (PJRT plugin not vendored; ref-kernel engine)",
        })
    }

    pub fn platform(&self) -> String {
        self.platform.to_string()
    }
}

/// Native kernels mirroring `python/compile/kernels/ref.py`. All
/// matrices are row-major flat `f32` slices shaped by a
/// [`ParamShape`].
pub mod kernels {
    use super::ParamShape;

    /// Decayed rank-k update in place: `S ← decay·S + lr·U·Vᵀ`.
    /// Returns the convergence metric `mean(S'²)` (the value the
    /// end-to-end driver logs as its loss curve).
    ///
    /// Shapes: `s: (m, n)`, `u: (m, k)`, `v: (n, k)`.
    pub fn rankk_update(s: &mut [f32], u: &[f32], v: &[f32], sh: &ParamShape) -> f32 {
        let (m, n, k) = (sh.m, sh.n, sh.k);
        assert_eq!(s.len(), m * n, "state shape");
        assert_eq!(u.len(), m * k, "left factor shape");
        assert_eq!(v.len(), n * k, "right factor shape");
        let mut sumsq = 0f64;
        for i in 0..m {
            let urow = &u[i * k..(i + 1) * k];
            let srow = &mut s[i * n..(i + 1) * n];
            for (j, sij) in srow.iter_mut().enumerate() {
                let vrow = &v[j * k..(j + 1) * k];
                let mut t = 0f32;
                for kk in 0..k {
                    t += urow[kk] * vrow[kk];
                }
                let next = sh.decay * *sij + sh.lr * t;
                *sij = next;
                sumsq += (next as f64) * (next as f64);
            }
        }
        (sumsq / (m * n) as f64) as f32
    }

    /// Serving-side probe: `Y = S·X`. Shapes: `s: (m, n)`, `x: (n, c)`,
    /// result `(m, c)`.
    pub fn apply(s: &[f32], x: &[f32], sh: &ParamShape) -> Vec<f32> {
        let (m, n, c) = (sh.m, sh.n, sh.c);
        assert_eq!(s.len(), m * n, "state shape");
        assert_eq!(x.len(), n * c, "probe shape");
        let mut y = vec![0f32; m * c];
        for i in 0..m {
            let srow = &s[i * n..(i + 1) * n];
            let yrow = &mut y[i * c..(i + 1) * c];
            for (j, &sij) in srow.iter().enumerate() {
                let xrow = &x[j * c..(j + 1) * c];
                for cc in 0..c {
                    yrow[cc] += sij * xrow[cc];
                }
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_engine_comes_up() {
        let rt = XlaRuntime::cpu().expect("native engine");
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn rankk_update_matches_closed_form() {
        // S = 0, U row pattern [1, 0, ...], V = ones → S' = lr·U·Vᵀ = lr
        // everywhere (each entry is the dot of e1 with a ones-row).
        let sh = ParamShape {
            m: 4,
            n: 5,
            k: 3,
            c: 1,
            decay: 0.99,
            lr: 0.05,
        };
        let mut s = vec![0f32; sh.m * sh.n];
        let mut u = vec![0f32; sh.m * sh.k];
        for i in 0..sh.m {
            u[i * sh.k] = 1.0;
        }
        let v = vec![1f32; sh.n * sh.k];
        let metric = kernels::rankk_update(&mut s, &u, &v, &sh);
        for &x in &s {
            assert!((x - 0.05).abs() < 1e-6, "expected lr*1, got {x}");
        }
        assert!((metric - 0.05 * 0.05).abs() < 1e-6, "metric {metric}");
    }

    #[test]
    fn rankk_update_applies_decay() {
        let sh = ParamShape {
            m: 2,
            n: 2,
            k: 1,
            c: 1,
            decay: 0.5,
            lr: 0.05,
        };
        let mut s = vec![1f32; sh.m * sh.n];
        let u = vec![0f32; sh.m * sh.k]; // zero update: pure decay
        let v = vec![0f32; sh.n * sh.k];
        let metric = kernels::rankk_update(&mut s, &u, &v, &sh);
        for &x in &s {
            assert!((x - 0.5).abs() < 1e-7);
        }
        assert!((metric - 0.25).abs() < 1e-7);
    }

    #[test]
    fn apply_is_matmul() {
        // S: 2·I (3x3), X: (3x2) → Y = 2·X.
        let sh = ParamShape {
            m: 3,
            n: 3,
            k: 1,
            c: 2,
            ..Default::default()
        };
        let mut s = vec![0f32; sh.m * sh.n];
        for i in 0..sh.m {
            s[i * sh.n + i] = 2.0;
        }
        let x: Vec<f32> = (0..sh.n * sh.c).map(|i| i as f32).collect();
        let y = kernels::apply(&s, &x, &sh);
        for i in 0..y.len() {
            assert!((y[i] - 2.0 * x[i]).abs() < 1e-6);
        }
    }
}
