//! Lock-protected parameter server — the end-to-end workload (E9).
//!
//! Shared state: an `(m, n)` f32 matrix updated via the AOT-compiled
//! `step` executable (decayed rank-k update + convergence metric) and
//! read via `apply` (probe multiplication). All mutation happens inside
//! a critical section of whichever [`crate::locks::SharedLock`] the
//! experiment selects; the [`ParamServer`] itself is lock-agnostic so
//! E9 can compare qplock against the baselines with identical compute.
//!
//! Threading: the `xla` crate's PJRT handles are `Rc`-based and not
//! `Send`, so the server owns a dedicated **engine thread** that holds
//! the client, the compiled executables, and the state; simulated
//! processes talk to it over an mpsc channel. The channel hop is ~1 µs
//! against a ~ms XLA step, and requests are serialized by the lock
//! under test anyway. Python never runs here — the artifacts were
//! compiled once by `make artifacts`.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::XlaRuntime;
use crate::util::prng::Prng;

/// Dimensions must match the AOT artifacts (see `artifacts/manifest.txt`).
#[derive(Clone, Copy, Debug)]
pub struct ParamShape {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub c: usize,
}

impl Default for ParamShape {
    fn default() -> Self {
        // aot.py defaults.
        ParamShape {
            m: 256,
            n: 256,
            k: 8,
            c: 4,
        }
    }
}

enum Request {
    Step {
        u: Vec<f32>,
        v: Vec<f32>,
        reply: mpsc::Sender<Result<f32>>,
    },
    Apply {
        x: Vec<f32>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    StateMsq {
        reply: mpsc::Sender<f32>,
    },
    Shutdown,
}

/// The protected shared state plus its compiled compute, behind the
/// engine thread.
pub struct ParamServer {
    tx: mpsc::Sender<Request>,
    worker: Option<JoinHandle<()>>,
    shape: ParamShape,
}

impl ParamServer {
    /// Load both artifacts from `dir` (normally `artifacts/`) into a
    /// fresh engine thread. `_rt` is accepted for API symmetry but the
    /// engine thread creates its own client (PJRT handles cannot cross
    /// threads).
    pub fn load(_rt: &XlaRuntime, dir: &str, shape: ParamShape) -> Result<ParamServer> {
        let dir = dir.to_string();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::spawn(move || {
            let setup = (|| -> Result<_> {
                let rt = XlaRuntime::cpu()?;
                let step = rt
                    .load(format!("{dir}/step.hlo.txt"))
                    .context("loading step artifact (run `make artifacts`)")?;
                let apply = rt
                    .load(format!("{dir}/apply.hlo.txt"))
                    .context("loading apply artifact")?;
                Ok((rt, step, apply))
            })();
            let (_rt, step_engine, apply_engine) = match setup {
                Ok(x) => {
                    let _ = ready_tx.send(Ok(()));
                    x
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let mut state = vec![0f32; shape.m * shape.n];
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Step { u, v, reply } => {
                        let res = step_engine
                            .run_f32(&[
                                (&state, &[shape.m as i64, shape.n as i64]),
                                (&u, &[shape.m as i64, shape.k as i64]),
                                (&v, &[shape.n as i64, shape.k as i64]),
                            ])
                            .and_then(|outs| {
                                anyhow::ensure!(outs.len() == 2, "step returns (state, metric)");
                                state.copy_from_slice(&outs[0]);
                                Ok(outs[1][0])
                            });
                        let _ = reply.send(res);
                    }
                    Request::Apply { x, reply } => {
                        let res = apply_engine
                            .run_f32(&[
                                (&state, &[shape.m as i64, shape.n as i64]),
                                (&x, &[shape.n as i64, shape.c as i64]),
                            ])
                            .map(|outs| outs.into_iter().next().unwrap());
                        let _ = reply.send(res);
                    }
                    Request::StateMsq { reply } => {
                        let msq =
                            state.iter().map(|x| x * x).sum::<f32>() / state.len() as f32;
                        let _ = reply.send(msq);
                    }
                    Request::Shutdown => break,
                }
            }
        });
        ready_rx
            .recv()
            .context("engine thread died during setup")??;
        Ok(ParamServer {
            tx,
            worker: Some(worker),
            shape,
        })
    }

    pub fn shape(&self) -> ParamShape {
        self.shape
    }

    /// One protected write: `S ← decay·S + lr·U·Vᵀ`; returns the
    /// convergence metric `mean(S'^2)`. **Caller must hold the lock
    /// under test** — the engine thread serializes requests but is not
    /// the synchronization mechanism being evaluated.
    pub fn step(&self, u: &[f32], v: &[f32]) -> Result<f32> {
        assert_eq!(u.len(), self.shape.m * self.shape.k);
        assert_eq!(v.len(), self.shape.n * self.shape.k);
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Step {
                u: u.to_vec(),
                v: v.to_vec(),
                reply,
            })
            .context("engine thread gone")?;
        rx.recv().context("engine thread dropped the request")?
    }

    /// One protected read: `Y = S @ X`. Caller must hold the lock.
    pub fn apply(&self, x: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(x.len(), self.shape.n * self.shape.c);
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Apply { x: x.to_vec(), reply })
            .context("engine thread gone")?;
        rx.recv().context("engine thread dropped the request")?
    }

    /// Deterministic per-step synthetic "gradient sketch" factors.
    pub fn synth_factors(&self, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let sh = self.shape;
        let mut rng = Prng::seed_from(seed);
        let mut gauss = move || {
            // Irwin–Hall(6) approximation of a Gaussian; plenty for a
            // workload generator.
            (0..6).map(|_| rng.f64()).sum::<f64>() as f32 / 3.0 - 1.0
        };
        let u: Vec<f32> = (0..sh.m * sh.k).map(|_| gauss()).collect();
        let v: Vec<f32> = (0..sh.n * sh.k).map(|_| gauss()).collect();
        (u, v)
    }

    /// Frobenius-mean-square of the current state (readback for
    /// assertions and logging).
    pub fn state_msq(&self) -> f32 {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::StateMsq { reply })
            .expect("engine thread gone");
        rx.recv().expect("engine thread dropped the request")
    }
}

impl Drop for ParamServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}
