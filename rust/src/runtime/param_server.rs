//! Lock-protected parameter server — the end-to-end workload (E9).
//!
//! Shared state: an `(m, n)` f32 matrix updated via the native `step`
//! kernel (decayed rank-k update + convergence metric) and read via
//! `apply` (probe multiplication). All mutation happens inside a
//! critical section of whichever [`crate::locks::SharedLock`] the
//! experiment selects; the [`ParamServer`] itself is lock-agnostic so
//! E9 can compare qplock against the baselines with identical compute.
//!
//! The state sits behind an internal `Mutex` purely so the server is
//! `Sync` (simulated processes are OS threads). That mutex is **not**
//! the synchronization under test — callers hold the distributed lock
//! around `step`/`apply` so E9 measures each lock's coordination cost
//! over identical compute. Note the inner mutex *does* serialize engine
//! access on its own, so lock-correctness is observed by the runner's
//! `CsChecker` oracle (which brackets the whole critical section), not
//! by state corruption here.

use std::sync::Mutex;

use super::{kernels, ParamShape, Result, RuntimeError, XlaRuntime};
use crate::util::prng::Prng;

/// The protected shared state plus its compute kernels.
pub struct ParamServer {
    state: Mutex<Vec<f32>>,
    shape: ParamShape,
}

impl ParamServer {
    /// Fresh zero state with the given shape/constants.
    pub fn new(shape: ParamShape) -> ParamServer {
        ParamServer {
            state: Mutex::new(vec![0f32; shape.m * shape.n]),
            shape,
        }
    }

    /// Constructor kept signature-compatible with the PJRT-era API:
    /// `dir` used to hold AOT HLO artifacts. The native engine needs no
    /// artifacts, so the directory is accepted and ignored — only the
    /// shape is validated.
    pub fn load(_rt: &XlaRuntime, _dir: &str, shape: ParamShape) -> Result<ParamServer> {
        if shape.m == 0 || shape.n == 0 || shape.k == 0 {
            return Err(RuntimeError(format!("degenerate shape {shape:?}")));
        }
        Ok(ParamServer::new(shape))
    }

    pub fn shape(&self) -> ParamShape {
        self.shape
    }

    /// One protected write: `S ← decay·S + lr·U·Vᵀ`; returns the
    /// convergence metric `mean(S'²)`. **Caller must hold the lock
    /// under test** — see the module docs.
    pub fn step(&self, u: &[f32], v: &[f32]) -> Result<f32> {
        let sh = self.shape;
        if u.len() != sh.m * sh.k || v.len() != sh.n * sh.k {
            return Err(RuntimeError(format!(
                "factor shapes {}x? / {}x? do not match {sh:?}",
                u.len(),
                v.len()
            )));
        }
        let mut state = self.state.lock().unwrap();
        Ok(kernels::rankk_update(&mut state, u, v, &sh))
    }

    /// One protected read: `Y = S·X`. Caller must hold the lock.
    pub fn apply(&self, x: &[f32]) -> Result<Vec<f32>> {
        let sh = self.shape;
        if x.len() != sh.n * sh.c {
            return Err(RuntimeError(format!(
                "probe length {} does not match {sh:?}",
                x.len()
            )));
        }
        let state = self.state.lock().unwrap();
        Ok(kernels::apply(&state, x, &sh))
    }

    /// Deterministic per-step synthetic "gradient sketch" factors.
    pub fn synth_factors(&self, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let sh = self.shape;
        let mut rng = Prng::seed_from(seed);
        let mut gauss = move || {
            // Irwin–Hall(6) approximation of a Gaussian; plenty for a
            // workload generator.
            (0..6).map(|_| rng.f64()).sum::<f64>() as f32 / 3.0 - 1.0
        };
        let u: Vec<f32> = (0..sh.m * sh.k).map(|_| gauss()).collect();
        let v: Vec<f32> = (0..sh.n * sh.k).map(|_| gauss()).collect();
        (u, v)
    }

    /// Frobenius-mean-square of the current state (readback for
    /// assertions and logging).
    pub fn state_msq(&self) -> f32 {
        let state = self.state.lock().unwrap();
        state.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>() as f32
            / state.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_rejects_degenerate_shapes() {
        let rt = XlaRuntime::cpu().unwrap();
        let bad = ParamShape {
            m: 0,
            ..Default::default()
        };
        assert!(ParamServer::load(&rt, "unused", bad).is_err());
    }

    #[test]
    fn step_and_apply_validate_input_lengths() {
        let ps = ParamServer::new(ParamShape::default());
        assert!(ps.step(&[0f32; 3], &[0f32; 3]).is_err());
        assert!(ps.apply(&[0f32; 3]).is_err());
    }

    #[test]
    fn metric_matches_state_msq_readback() {
        let ps = ParamServer::new(ParamShape::default());
        let (u, v) = ps.synth_factors(42);
        let m1 = ps.step(&u, &v).unwrap();
        let m2 = ps.state_msq();
        assert!(
            (m1 - m2).abs() <= 1e-6 * m1.abs().max(1e-12),
            "engine metric {m1} vs readback {m2}"
        );
    }
}
