//! Tarjan SCC decomposition + weak-fairness liveness analysis.
//!
//! A violation of `q wants ~> q in cs` is a lasso whose cycle (a) never
//! visits a state where `q` is in its critical section, (b) keeps `q`
//! wanting, and (c) is **weakly fair**: every process that is
//! continuously enabled along the cycle takes steps inside it.
//!
//! Because (a)/(b) are state predicates, the analysis is exact: restrict
//! the graph to states satisfying `wants(q) ∧ ¬cs(q)`, decompose the
//! *restricted* subgraph into SCCs, and test each cyclic SCC for weak
//! fairness — for every process `p`, either some state in the SCC has
//! `p` disabled (so weak fairness demands nothing of `p` there) or `p`
//! has an edge that stays inside the SCC (so a fair run can satisfy
//! `p`'s obligation without leaving). A fair restricted SCC reachable
//! from an initial state is a genuine counterexample; absence of one is
//! a proof (for the finite configuration).

use super::graph::{StateGraph, StateId};
use super::Model;

/// One strongly connected component (state ids).
pub struct Scc {
    pub members: Vec<StateId>,
    /// Has at least one internal edge (admits infinite runs).
    pub cyclic: bool,
}

/// Iterative Tarjan over the subgraph induced by `mask` (explicit stack:
/// graphs reach millions of states). States with `mask[s] == false` are
/// skipped entirely.
pub fn tarjan_masked<S>(g: &StateGraph<S>, mask: &[bool]) -> Vec<Scc> {
    let n = g.states.len();
    debug_assert_eq!(mask.len(), n);
    let mut index = vec![u32::MAX; n];
    let mut low = vec![0u32; n];
    let mut on_stack = vec![false; n];
    let mut stack: Vec<StateId> = vec![];
    let mut next_index = 0u32;
    let mut sccs = vec![];

    for root in 0..n as StateId {
        if !mask[root as usize] || index[root as usize] != u32::MAX {
            continue;
        }
        let mut dfs: Vec<(StateId, usize)> = vec![(root, 0)];
        index[root as usize] = next_index;
        low[root as usize] = next_index;
        next_index += 1;
        stack.push(root);
        on_stack[root as usize] = true;

        while let Some(&mut (v, ref mut cursor)) = dfs.last_mut() {
            if *cursor < g.edges[v as usize].len() {
                let (_, w) = g.edges[v as usize][*cursor];
                *cursor += 1;
                if !mask[w as usize] {
                    continue;
                }
                if index[w as usize] == u32::MAX {
                    index[w as usize] = next_index;
                    low[w as usize] = next_index;
                    next_index += 1;
                    stack.push(w);
                    on_stack[w as usize] = true;
                    dfs.push((w, 0));
                } else if on_stack[w as usize] {
                    low[v as usize] = low[v as usize].min(index[w as usize]);
                }
            } else {
                dfs.pop();
                if let Some(&mut (parent, _)) = dfs.last_mut() {
                    low[parent as usize] = low[parent as usize].min(low[v as usize]);
                }
                if low[v as usize] == index[v as usize] {
                    let mut members = vec![];
                    loop {
                        let w = stack.pop().unwrap();
                        on_stack[w as usize] = false;
                        members.push(w);
                        if w == v {
                            break;
                        }
                    }
                    let cyclic = members.len() > 1
                        || g.edges[v as usize]
                            .iter()
                            .any(|&(_, d)| d == v && mask[v as usize]);
                    sccs.push(Scc { members, cyclic });
                }
            }
        }
    }
    sccs
}

/// Tarjan over the full graph.
pub fn tarjan<S>(g: &StateGraph<S>) -> Vec<Scc> {
    tarjan_masked(g, &vec![true; g.states.len()])
}

/// Is the cyclic SCC weakly fair? For every process: disabled somewhere
/// inside, or has an internal edge.
fn scc_is_fair<M: Model>(model: &M, g: &StateGraph<M::State>, scc: &Scc) -> bool {
    let in_scc: std::collections::HashSet<StateId> = scc.members.iter().copied().collect();
    let nproc = model.procs();
    let mut internal_move = vec![false; nproc];
    for &sid in &scc.members {
        for &(pid, dst) in &g.edges[sid as usize] {
            if in_scc.contains(&dst) {
                internal_move[pid as usize] = true;
            }
        }
    }
    (0..nproc).all(|p| {
        internal_move[p]
            || scc
                .members
                .iter()
                .any(|&sid| model.step(&g.states[sid as usize], p).is_none())
    })
}

/// A starvation counterexample: a fair cycle on which `pid` waits
/// forever.
pub struct Starvation {
    pub pid: usize,
    /// A representative state inside the fair SCC.
    pub witness: StateId,
    pub scc_size: usize,
}

/// Find weak-fairness violations of `enter ~> cs` (per process), and of
/// the paper's `DeadAndLivelockFree` (`someone wants ~> someone in cs`).
pub fn find_starvation<M: Model>(
    model: &M,
    g: &StateGraph<M::State>,
) -> (Vec<Starvation>, bool) {
    let nproc = model.procs();
    let nstates = g.states.len();
    let mut starved = vec![];

    // Per-process starvation: restrict to wants(q) ∧ ¬cs(q).
    for q in 0..nproc {
        let mask: Vec<bool> = (0..nstates)
            .map(|i| {
                let s = &g.states[i];
                model.wants_cs(s, q) && !model.in_cs(s, q)
            })
            .collect();
        for scc in tarjan_masked(g, &mask) {
            if scc.cyclic && scc_is_fair(model, g, &scc) {
                starved.push(Starvation {
                    pid: q,
                    witness: scc.members[0],
                    scc_size: scc.members.len(),
                });
                break; // one witness per process suffices
            }
        }
    }

    // Livelock: restrict to (∃p wants) ∧ (∀p ¬cs).
    let mask: Vec<bool> = (0..nstates)
        .map(|i| {
            let s = &g.states[i];
            (0..nproc).any(|p| model.wants_cs(s, p))
                && (0..nproc).all(|p| !model.in_cs(s, p))
        })
        .collect();
    let livelock = tarjan_masked(g, &mask)
        .into_iter()
        .any(|scc| scc.cyclic && scc_is_fair(model, g, &scc));

    (starved, livelock)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mc::graph::explore;
    use crate::mc::Model;

    /// Ring model: single process cycling through k states (state 1 is
    /// its critical section).
    struct Ring(u8);
    impl Model for Ring {
        type State = u8;
        fn initials(&self) -> Vec<u8> {
            vec![0]
        }
        fn procs(&self) -> usize {
            1
        }
        fn step(&self, s: &u8, _pid: usize) -> Option<u8> {
            Some((s + 1) % self.0)
        }
        fn in_cs(&self, s: &u8, _pid: usize) -> bool {
            *s == 1
        }
        fn wants_cs(&self, _s: &u8, _pid: usize) -> bool {
            true
        }
        fn pc_name(&self, s: &u8, _pid: usize) -> String {
            format!("{s}")
        }
        fn name(&self) -> &'static str {
            "ring"
        }
    }

    #[test]
    fn ring_is_one_cyclic_scc() {
        let r = explore(&Ring(5), 1 << 10);
        let sccs = tarjan(&r.graph);
        assert_eq!(sccs.len(), 1);
        assert!(sccs[0].cyclic);
        assert_eq!(sccs[0].members.len(), 5);
    }

    #[test]
    fn ring_reaching_cs_is_not_starving() {
        // Restricted to ¬cs states the ring is a path, not a cycle: no
        // starvation.
        let r = explore(&Ring(5), 1 << 10);
        let (starved, livelock) = find_starvation(&Ring(5), &r.graph);
        assert!(starved.is_empty());
        assert!(!livelock);
    }

    /// Two processes; p0 spins forever between two non-cs states (always
    /// enabled, always wanting); p1 oscillates through its cs.
    struct Starver;
    impl Model for Starver {
        type State = (u8, u8);
        fn initials(&self) -> Vec<(u8, u8)> {
            vec![(0, 0)]
        }
        fn procs(&self) -> usize {
            2
        }
        fn step(&self, s: &(u8, u8), pid: usize) -> Option<(u8, u8)> {
            let mut n = *s;
            if pid == 0 {
                n.0 = (n.0 + 1) % 2; // never reaches a cs state
            } else {
                n.1 = (n.1 + 1) % 3; // state 2 is its cs
            }
            Some(n)
        }
        fn in_cs(&self, s: &(u8, u8), pid: usize) -> bool {
            pid == 1 && s.1 == 2
        }
        fn wants_cs(&self, _s: &(u8, u8), pid: usize) -> bool {
            pid == 0
        }
        fn pc_name(&self, _s: &(u8, u8), _pid: usize) -> String {
            String::new()
        }
        fn name(&self) -> &'static str {
            "starver"
        }
    }

    #[test]
    fn detects_starvation() {
        let r = explore(&Starver, 1 << 10);
        let (starved, _) = find_starvation(&Starver, &r.graph);
        assert!(starved.iter().any(|s| s.pid == 0));
        assert!(!starved.iter().any(|s| s.pid == 1));
    }

    /// Blocked process: p0 is disabled forever while p1 cycles outside
    /// its cs — fair w.r.t. p0 because p0 is disabled; p0 starves.
    struct Blocked;
    impl Model for Blocked {
        type State = u8;
        fn initials(&self) -> Vec<u8> {
            vec![0]
        }
        fn procs(&self) -> usize {
            2
        }
        fn step(&self, s: &u8, pid: usize) -> Option<u8> {
            if pid == 0 {
                None
            } else {
                Some((s + 1) % 3)
            }
        }
        fn in_cs(&self, _s: &u8, _pid: usize) -> bool {
            false
        }
        fn wants_cs(&self, _s: &u8, pid: usize) -> bool {
            pid == 0
        }
        fn pc_name(&self, _s: &u8, _pid: usize) -> String {
            String::new()
        }
        fn name(&self) -> &'static str {
            "blocked"
        }
    }

    #[test]
    fn disabled_process_starves_fairly() {
        let r = explore(&Blocked, 1 << 10);
        let (starved, _) = find_starvation(&Blocked, &r.graph);
        assert!(starved.iter().any(|s| s.pid == 0));
    }

    /// p0 is continuously enabled in the cycle but never moves inside it
    /// (its only edge exits the restricted region): weak fairness rules
    /// the cycle out — no starvation.
    struct MustExit;
    impl Model for MustExit {
        // (p0 done?, p1 phase)
        type State = (bool, u8);
        fn initials(&self) -> Vec<(bool, u8)> {
            vec![(false, 0)]
        }
        fn procs(&self) -> usize {
            2
        }
        fn step(&self, s: &(bool, u8), pid: usize) -> Option<(bool, u8)> {
            let mut n = *s;
            if pid == 0 {
                if s.0 {
                    return None; // done
                }
                n.0 = true; // p0's single step reaches its cs (exits wants-region)
            } else {
                n.1 = (n.1 + 1) % 2;
            }
            Some(n)
        }
        fn in_cs(&self, s: &(bool, u8), pid: usize) -> bool {
            pid == 0 && s.0
        }
        fn wants_cs(&self, s: &(bool, u8), pid: usize) -> bool {
            pid == 0 && !s.0
        }
        fn pc_name(&self, _s: &(bool, u8), _pid: usize) -> String {
            String::new()
        }
        fn name(&self) -> &'static str {
            "must-exit"
        }
    }

    #[test]
    fn continuously_enabled_exit_edge_defeats_the_cycle() {
        let r = explore(&MustExit, 1 << 10);
        let (starved, _) = find_starvation(&MustExit, &r.graph);
        assert!(
            starved.is_empty(),
            "weak fairness forces p0 to take its always-enabled step"
        );
    }
}
