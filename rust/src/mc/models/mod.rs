//! Transition-system models checked by the `mc` engine (system S7).
//!
//! * [`qplock_spec`] — label-for-label transcription of the paper's
//!   Appendix A PlusCal algorithm (the artifact the authors model
//!   checked with TLC).
//! * [`peterson_spec`] — classic two-process Peterson; sanity baseline
//!   for the checker itself.
//! * [`naive_spec`] — the mixed-atomicity TAS lock with the remote CAS
//!   split into its NIC-read and NIC-write halves; exhibits the Table-1
//!   mutual-exclusion violation.
//! * [`spin_spec`] — everyone-through-the-NIC TAS lock (remote CAS
//!   atomic): safe but *not* starvation-free, which the fairness
//!   analysis detects.

pub mod naive_spec;
pub mod peterson_spec;
pub mod qplock_spec;
pub mod spin_spec;
