//! The everyone-through-the-NIC TAS lock (`spin-rcas`), modeled with an
//! *atomic* remote CAS — the NIC serializes all RMWs, so with every
//! process using `rCAS` the compare-and-swap is a single step for all.
//!
//! Safe (mutual exclusion holds — contrast with [`super::naive_spec`]),
//! but a TAS lock is **not starvation-free**: two processes can hand the
//! lock between... no — one process can acquire and release repeatedly
//! while the other happens never to win the race. The weak-fairness SCC
//! analysis exposes exactly that, giving E8 its qplock-vs-TAS fairness
//! row (and matching the paper's emphasis on starvation freedom as a
//! distinguishing property).

use crate::mc::Model;

const NCS: u8 = 0;
const TRY: u8 = 1;
const CS: u8 = 2;
const EXIT: u8 = 3;

/// State: `[word, pc...]` for `n` processes; `word` = 0 or owner pid.
pub struct SpinSpec {
    pub n: usize,
}

impl SpinSpec {
    pub fn new(n: usize) -> SpinSpec {
        assert!((2..=6).contains(&n));
        SpinSpec { n }
    }
}

impl Model for SpinSpec {
    type State = [u8; 7];

    fn initials(&self) -> Vec<[u8; 7]> {
        vec![[0; 7]]
    }

    fn procs(&self) -> usize {
        self.n
    }

    fn step(&self, s: &[u8; 7], pid: usize) -> Option<[u8; 7]> {
        let mut n = *s;
        match s[1 + pid] {
            NCS => n[1 + pid] = TRY,
            TRY => {
                // Atomic CAS (NIC-serialized); blocked while held.
                if s[0] == 0 {
                    n[0] = pid as u8 + 1;
                    n[1 + pid] = CS;
                } else {
                    return None;
                }
            }
            CS => n[1 + pid] = EXIT,
            EXIT => {
                n[0] = 0;
                n[1 + pid] = NCS;
            }
            _ => unreachable!(),
        }
        Some(n)
    }

    fn in_cs(&self, s: &[u8; 7], pid: usize) -> bool {
        s[1 + pid] == CS
    }

    fn wants_cs(&self, s: &[u8; 7], pid: usize) -> bool {
        s[1 + pid] == TRY
    }

    fn pc_name(&self, s: &[u8; 7], pid: usize) -> String {
        match s[1 + pid] {
            NCS => "ncs",
            TRY => "try",
            CS => "cs",
            EXIT => "exit",
            _ => "?",
        }
        .to_string()
    }

    fn name(&self) -> &'static str {
        "spin-rcas-spec"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mc::check_all;

    #[test]
    fn safe_but_not_starvation_free() {
        let r = check_all(&SpinSpec::new(2), 1 << 16);
        assert!(r.mutual_exclusion.holds(), "{}", r.mutual_exclusion);
        assert!(r.deadlock_free.holds(), "{}", r.deadlock_free);
        assert!(
            !r.starvation_free.holds(),
            "TAS locks admit starvation; the fairness analysis must find it"
        );
        // But it is livelock-free: someone always gets in.
        assert!(r.dead_and_livelock_free.holds(), "{}", r.dead_and_livelock_free);
    }

    #[test]
    fn three_process_variant_too() {
        let r = check_all(&SpinSpec::new(3), 1 << 18);
        assert!(r.mutual_exclusion.holds());
        assert!(!r.starvation_free.holds());
    }
}
