//! Classic two-process Peterson lock — checker sanity baseline.
//!
//! State: `[flag0, flag1, victim, pc0, pc1]`.

use crate::mc::Model;

const NCS: u8 = 0;
const SET_FLAG: u8 = 1;
const SET_VICTIM: u8 = 2;
const WAIT: u8 = 3;
const CS: u8 = 4;
const EXIT: u8 = 5;

/// Two-process Peterson over atomic read/write registers.
pub struct PetersonSpec;

impl Model for PetersonSpec {
    type State = [u8; 5];

    fn initials(&self) -> Vec<[u8; 5]> {
        vec![[0, 0, 0, NCS, NCS]]
    }

    fn procs(&self) -> usize {
        2
    }

    fn step(&self, s: &[u8; 5], pid: usize) -> Option<[u8; 5]> {
        let me = pid;
        let other = 1 - pid;
        let mut n = *s;
        let pc = s[3 + me];
        match pc {
            NCS => n[3 + me] = SET_FLAG,
            SET_FLAG => {
                n[me] = 1;
                n[3 + me] = SET_VICTIM;
            }
            SET_VICTIM => {
                n[2] = me as u8;
                n[3 + me] = WAIT;
            }
            WAIT => {
                // Busy-wait modeled as stuttering: enabled only when the
                // exit condition holds.
                if s[other] == 0 || s[2] != me as u8 {
                    n[3 + me] = CS;
                } else {
                    return None;
                }
            }
            CS => n[3 + me] = EXIT,
            EXIT => {
                n[me] = 0;
                n[3 + me] = NCS;
            }
            _ => unreachable!(),
        }
        Some(n)
    }

    fn in_cs(&self, s: &[u8; 5], pid: usize) -> bool {
        s[3 + pid] == CS
    }

    fn wants_cs(&self, s: &[u8; 5], pid: usize) -> bool {
        matches!(s[3 + pid], SET_FLAG | SET_VICTIM | WAIT)
    }

    fn pc_name(&self, s: &[u8; 5], pid: usize) -> String {
        match s[3 + pid] {
            NCS => "ncs",
            SET_FLAG => "set_flag",
            SET_VICTIM => "set_victim",
            WAIT => "wait",
            CS => "cs",
            EXIT => "exit",
            _ => "?",
        }
        .to_string()
    }

    fn name(&self) -> &'static str {
        "peterson-2p"
    }
}

#[cfg(test)]
mod tests {
    use crate::mc::{check_all, models::peterson_spec::PetersonSpec};

    #[test]
    fn peterson_full_battery() {
        let r = check_all(&PetersonSpec, 1 << 16);
        assert!(r.mutual_exclusion.holds(), "{}", r.mutual_exclusion);
        assert!(r.deadlock_free.holds(), "{}", r.deadlock_free);
        assert!(r.starvation_free.holds(), "{}", r.starvation_free);
        assert!(
            r.dead_and_livelock_free.holds(),
            "{}",
            r.dead_and_livelock_free
        );
        assert!(!r.truncated);
        assert!(r.states > 10 && r.states < 200);
    }
}
