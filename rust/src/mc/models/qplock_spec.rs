//! Transcription of the paper's Appendix A PlusCal algorithm (`qplock`).
//!
//! Every PlusCal label is one atomic step, exactly as TLC would execute
//! it. Process ids are 1-based (`self ∈ 1..NP`); the class of a process
//! is its parity — `Us(pid) = (pid % 2) + 1` in the paper, index
//! `pid % 2` here — so odd pids form one cohort and even pids the other
//! (the PlusCal stand-in for local vs remote locality).
//!
//! Shared variables: `victim` (a pid), `cohort[2]` (pid or 0 — the
//! PlusCal abstraction of the MCS tail word), `descriptor[pid] =
//! {budget, next}`, `passed[pid]`. The procedure-call structure
//! (`AcquireGlobal` invoked from both `c5` and `p2`) is compiled into
//! distinct pc labels carrying the return site.
//!
//! One divergence from the appendix *text*: its `ReleaseCohort` prints
//! `r1`/`r2` inside the `then` branch of the `cas` test. Taken
//! literally, a process that successfully resets `cohort` would then
//! await a successor that may never arrive — deadlocking even a lone
//! process (TLC would reject it instantly). Algorithm 2's `qUnlock()`
//! gives the evident intent: `r1`/`r2` are the *else* branch (pass the
//! lock when the tail CAS fails). We transcribe that reading, and the
//! E8 battery (every property PASS for every checked configuration)
//! confirms it reproduces the paper's verification claims.

use crate::mc::Model;

/// Maximum processes supported by the packed state layout.
pub const MAX_PROCS: usize = 6;

// Program counter labels.
const NCS: u8 = 0;
const C1: u8 = 1;
const SWAP: u8 = 2;
const CWAIT: u8 = 3;
const C2: u8 = 4;
const C3: u8 = 5;
const C4: u8 = 6;
const C6: u8 = 7;
const C7: u8 = 8;
const C8: u8 = 9;
const C9: u8 = 10;
const P2: u8 = 11;
const G1_C5: u8 = 12;
const G2_C5: u8 = 13;
const G3_C5: u8 = 14;
const G1_P2: u8 = 15;
const G2_P2: u8 = 16;
const G3_P2: u8 = 17;
const CS: u8 = 18;
const CASR: u8 = 19;
const R1: u8 = 20;
const R2: u8 = 21;

/// Budget field encoding: PlusCal value −1..B stored as `v + 1`.
const B_WAITING: u8 = 0; // −1

/// Packed state:
/// `[victim, cohort0, cohort1, then per proc: pc, pred, budget, next, passed]`.
pub type QpState = [u8; 3 + 5 * MAX_PROCS];

/// Configuration: process count and `InitialBudget` (paper constants
/// `NumProcesses`, `InitialBudget`).
pub struct QpSpec {
    pub n: usize,
    pub budget: u8,
}

impl QpSpec {
    pub fn new(n: usize, budget: u8) -> QpSpec {
        assert!((2..=MAX_PROCS).contains(&n));
        assert!(budget >= 1 && budget < 200);
        QpSpec { n, budget }
    }

    // Field accessors over the packed layout.
    #[inline]
    fn pc(s: &QpState, p: usize) -> u8 {
        s[3 + 5 * p]
    }
    #[inline]
    fn set_pc(s: &mut QpState, p: usize, v: u8) {
        s[3 + 5 * p] = v;
    }
    #[inline]
    fn pred(s: &QpState, p: usize) -> u8 {
        s[4 + 5 * p]
    }
    #[inline]
    fn set_pred(s: &mut QpState, p: usize, v: u8) {
        s[4 + 5 * p] = v;
    }
    /// Budget in PlusCal terms (−1 encoded as `B_WAITING`).
    #[inline]
    fn budget_raw(s: &QpState, p: usize) -> u8 {
        s[5 + 5 * p]
    }
    #[inline]
    fn set_budget_raw(s: &mut QpState, p: usize, v: u8) {
        s[5 + 5 * p] = v;
    }
    #[inline]
    fn next(s: &QpState, p: usize) -> u8 {
        s[6 + 5 * p]
    }
    #[inline]
    fn set_next(s: &mut QpState, p: usize, v: u8) {
        s[6 + 5 * p] = v;
    }
    #[inline]
    fn passed(s: &QpState, p: usize) -> bool {
        s[7 + 5 * p] != 0
    }
    #[inline]
    fn set_passed(s: &mut QpState, p: usize, v: bool) {
        s[7 + 5 * p] = v as u8;
    }

    /// `Us(pid)` as a 0-based cohort index (paper: `(pid % 2) + 1`).
    #[inline]
    fn us(pid1: u8) -> usize {
        (pid1 % 2) as usize
    }
    #[inline]
    fn them(pid1: u8) -> usize {
        1 - Self::us(pid1)
    }
}

impl Model for QpSpec {
    type State = QpState;

    fn initials(&self) -> Vec<QpState> {
        // victim ∈ {1, 2} (two initial states, as in the spec).
        let mut out = vec![];
        for v in [1u8, 2] {
            let mut s: QpState = [0; 3 + 5 * MAX_PROCS];
            s[0] = v;
            for p in 0..self.n {
                QpSpec::set_pc(&mut s, p, NCS);
                QpSpec::set_budget_raw(&mut s, p, B_WAITING); // budget −1
            }
            out.push(s);
        }
        out
    }

    fn procs(&self) -> usize {
        self.n
    }

    fn step(&self, s: &QpState, p: usize) -> Option<QpState> {
        let pid1 = (p + 1) as u8; // PlusCal `self`
        let us = QpSpec::us(pid1);
        let them = QpSpec::them(pid1);
        let mut n = *s;
        match QpSpec::pc(s, p) {
            // p1/ncs/enter: begin AcquireCohort.
            NCS => QpSpec::set_pc(&mut n, p, C1),
            // c1: descriptor[self] := {budget |-> -1, next |-> 0}
            C1 => {
                QpSpec::set_budget_raw(&mut n, p, B_WAITING);
                QpSpec::set_next(&mut n, p, 0);
                QpSpec::set_pc(&mut n, p, SWAP);
            }
            // swap: pred := cohort[Us]; cohort[Us] := self  (atomic)
            SWAP => {
                QpSpec::set_pred(&mut n, p, s[1 + us]);
                n[1 + us] = pid1;
                QpSpec::set_pc(&mut n, p, CWAIT);
            }
            // cwait: branch on pred
            CWAIT => {
                if QpSpec::pred(s, p) != 0 {
                    QpSpec::set_pc(&mut n, p, C2);
                } else {
                    QpSpec::set_pc(&mut n, p, C8);
                }
            }
            // c2: descriptor[pred].next := self
            C2 => {
                let pred = QpSpec::pred(s, p) as usize - 1;
                QpSpec::set_next(&mut n, pred, pid1);
                QpSpec::set_pc(&mut n, p, C3);
            }
            // c3: await Budget(self) >= 0
            C3 => {
                if QpSpec::budget_raw(s, p) == B_WAITING {
                    return None;
                }
                QpSpec::set_pc(&mut n, p, C4);
            }
            // c4: if Budget(self) = 0 then call AcquireGlobal (c5)
            C4 => {
                if QpSpec::budget_raw(s, p) == 1 {
                    // budget 0
                    QpSpec::set_pc(&mut n, p, G1_C5);
                } else {
                    QpSpec::set_pc(&mut n, p, C7);
                }
            }
            // c6: descriptor[self].budget := B
            C6 => {
                QpSpec::set_budget_raw(&mut n, p, self.budget + 1);
                QpSpec::set_pc(&mut n, p, C7);
            }
            // c7: passed[self] := TRUE; (c10: return → p2)
            C7 => {
                QpSpec::set_passed(&mut n, p, true);
                QpSpec::set_pc(&mut n, p, P2);
            }
            // c8: descriptor[self].budget := B
            C8 => {
                QpSpec::set_budget_raw(&mut n, p, self.budget + 1);
                QpSpec::set_pc(&mut n, p, C9);
            }
            // c9: passed[self] := FALSE; (c10: return → p2)
            C9 => {
                QpSpec::set_passed(&mut n, p, false);
                QpSpec::set_pc(&mut n, p, P2);
            }
            // p2: if ¬passed then call AcquireGlobal else → cs
            P2 => {
                if !QpSpec::passed(s, p) {
                    QpSpec::set_pc(&mut n, p, G1_P2);
                } else {
                    QpSpec::set_pc(&mut n, p, CS);
                }
            }
            // g1: victim := self
            G1_C5 | G1_P2 => {
                n[0] = pid1;
                QpSpec::set_pc(&mut n, p, if QpSpec::pc(s, p) == G1_C5 { G2_C5 } else { G2_P2 });
            }
            // g2: if cohort[Them] = 0 goto g4 (return)
            G2_C5 | G2_P2 => {
                let from_c5 = QpSpec::pc(s, p) == G2_C5;
                if s[1 + them] == 0 {
                    QpSpec::set_pc(&mut n, p, if from_c5 { C6 } else { CS });
                } else {
                    QpSpec::set_pc(&mut n, p, if from_c5 { G3_C5 } else { G3_P2 });
                }
            }
            // g3: if victim ≠ self goto g4 (return) else loop to g2
            G3_C5 | G3_P2 => {
                let from_c5 = QpSpec::pc(s, p) == G3_C5;
                if s[0] != pid1 {
                    QpSpec::set_pc(&mut n, p, if from_c5 { C6 } else { CS });
                } else {
                    QpSpec::set_pc(&mut n, p, if from_c5 { G2_C5 } else { G2_P2 });
                }
            }
            // cs: skip; exit: call ReleaseCohort
            CS => QpSpec::set_pc(&mut n, p, CASR),
            // cas: if cohort[Us] = self then cohort[Us] := 0 (success →
            // return) else pass the lock (r1/r2).
            CASR => {
                if s[1 + us] == pid1 {
                    n[1 + us] = 0;
                    QpSpec::set_pc(&mut n, p, NCS);
                } else {
                    QpSpec::set_pc(&mut n, p, R1);
                }
            }
            // r1: await descriptor[self].next ≠ 0
            R1 => {
                if QpSpec::next(s, p) == 0 {
                    return None;
                }
                QpSpec::set_pc(&mut n, p, R2);
            }
            // r2: descriptor[next].budget := Budget(self) − 1
            R2 => {
                let nxt = QpSpec::next(s, p) as usize - 1;
                let b = QpSpec::budget_raw(s, p);
                debug_assert!(b >= 2, "passing with budget {}", b as i16 - 1);
                QpSpec::set_budget_raw(&mut n, nxt, b - 1);
                QpSpec::set_pc(&mut n, p, NCS);
            }
            other => unreachable!("pc {other}"),
        }
        Some(n)
    }

    fn in_cs(&self, s: &QpState, p: usize) -> bool {
        QpSpec::pc(s, p) == CS
    }

    fn wants_cs(&self, s: &QpState, p: usize) -> bool {
        !matches!(QpSpec::pc(s, p), NCS | CS | CASR | R1 | R2)
    }

    fn pc_name(&self, s: &QpState, p: usize) -> String {
        match QpSpec::pc(s, p) {
            NCS => "ncs",
            C1 => "c1",
            SWAP => "swap",
            CWAIT => "cwait",
            C2 => "c2",
            C3 => "c3",
            C4 => "c4",
            C6 => "c6",
            C7 => "c7",
            C8 => "c8",
            C9 => "c9",
            P2 => "p2",
            G1_C5 => "g1(c5)",
            G2_C5 => "g2(c5)",
            G3_C5 => "g3(c5)",
            G1_P2 => "g1(p2)",
            G2_P2 => "g2(p2)",
            G3_P2 => "g3(p2)",
            CS => "cs",
            CASR => "cas",
            R1 => "r1",
            R2 => "r2",
            _ => "?",
        }
        .to_string()
    }

    fn name(&self) -> &'static str {
        "qplock-spec"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mc::check_all;

    #[test]
    fn two_procs_budget_one_full_battery() {
        let r = check_all(&QpSpec::new(2, 1), 1 << 22);
        assert!(r.mutual_exclusion.holds(), "{}", r.mutual_exclusion);
        assert!(r.deadlock_free.holds(), "{}", r.deadlock_free);
        assert!(r.starvation_free.holds(), "{}", r.starvation_free);
        assert!(r.dead_and_livelock_free.holds(), "{}", r.dead_and_livelock_free);
        assert!(!r.truncated);
    }

    #[test]
    fn three_procs_budget_two_full_battery() {
        let r = check_all(&QpSpec::new(3, 2), 1 << 22);
        assert!(r.mutual_exclusion.holds(), "{}", r.mutual_exclusion);
        assert!(r.deadlock_free.holds(), "{}", r.deadlock_free);
        assert!(r.starvation_free.holds(), "{}", r.starvation_free);
        assert!(r.dead_and_livelock_free.holds(), "{}", r.dead_and_livelock_free);
        assert!(!r.truncated);
    }

    #[test]
    fn four_procs_safety() {
        let r = check_all(&QpSpec::new(4, 2), 1 << 23);
        assert!(r.mutual_exclusion.holds(), "{}", r.mutual_exclusion);
        assert!(r.deadlock_free.holds(), "{}", r.deadlock_free);
    }
}
