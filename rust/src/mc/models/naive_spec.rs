//! The naive mixed-atomicity TAS lock, modeled at NIC granularity.
//!
//! One local process takes the lock word with CPU CAS (a single atomic
//! step — that is what the silicon gives it). One remote process uses
//! RDMA CAS, which commodity RNICs execute internally as a read followed
//! by a write that is **not** atomic with CPU accesses (paper Table 1).
//! We model that by splitting the remote CAS into two labels with the
//! read's result latched in a register — precisely the abstraction-level
//! consequence of `AtomicityMode::NicSerialized`.
//!
//! The checker finds the classic TOCTOU interleaving in a handful of
//! states; `spin_spec` (same lock, atomic remote CAS) shows the split is
//! the *only* difference.

use crate::mc::Model;

const NCS: u8 = 0;
/// Local: atomic CAS attempt. Remote: issue the NIC's internal read.
const TRY: u8 = 1;
/// Remote only: the NIC's internal conditional write (uses the latched
/// read).
const COMMIT: u8 = 2;
const CS: u8 = 3;
const EXIT: u8 = 4;

/// State: `[word, latched, pc_local, pc_remote]`; `word` holds 0 (free)
/// or owner pid (1 = local, 2 = remote).
pub struct NaiveSpec;

impl Model for NaiveSpec {
    type State = [u8; 4];

    fn initials(&self) -> Vec<[u8; 4]> {
        vec![[0, 0, NCS, NCS]]
    }

    fn procs(&self) -> usize {
        2
    }

    fn step(&self, s: &[u8; 4], pid: usize) -> Option<[u8; 4]> {
        let mut n = *s;
        let pc = s[2 + pid];
        match (pid, pc) {
            (_, NCS) => n[2 + pid] = TRY,
            // Local CPU CAS: one atomic step; blocked while held.
            (0, TRY) => {
                if s[0] == 0 {
                    n[0] = 1;
                    n[2] = CS;
                } else {
                    return None;
                }
            }
            // Remote NIC CAS, read half: latch the current word.
            (1, TRY) => {
                n[1] = s[0];
                n[3] = COMMIT;
            }
            // Remote NIC CAS, write half: commit based on the *latched*
            // value — the Table-1 hazard.
            (1, COMMIT) => {
                if s[1] == 0 {
                    n[0] = 2;
                    n[3] = CS;
                } else {
                    n[3] = TRY; // failed CAS: retry
                }
            }
            (_, CS) => n[2 + pid] = EXIT,
            (_, EXIT) => {
                n[0] = 0;
                n[2 + pid] = NCS;
            }
            _ => unreachable!(),
        }
        Some(n)
    }

    fn in_cs(&self, s: &[u8; 4], pid: usize) -> bool {
        s[2 + pid] == CS
    }

    fn wants_cs(&self, s: &[u8; 4], pid: usize) -> bool {
        matches!(s[2 + pid], TRY | COMMIT)
    }

    fn pc_name(&self, s: &[u8; 4], pid: usize) -> String {
        match s[2 + pid] {
            NCS => "ncs",
            TRY => "try",
            COMMIT => "commit",
            CS => "cs",
            EXIT => "exit",
            _ => "?",
        }
        .to_string()
    }

    fn name(&self) -> &'static str {
        "naive-mixed-spec"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mc::{check_all, graph::explore};

    #[test]
    fn checker_finds_the_table1_violation() {
        let r = check_all(&NaiveSpec, 1 << 16);
        assert!(
            !r.mutual_exclusion.holds(),
            "the mixed-atomicity lock must violate mutual exclusion"
        );
    }

    #[test]
    fn shortest_trace_is_the_toctou_interleaving() {
        let r = explore(&NaiveSpec, 1 << 16);
        let vid = r.me_violation.expect("violation");
        // ncs,ncs → remote try (read 0) → local try (cas wins) → local
        // cs… remote commit (stale 0) → both cs. Shortest trace ≤ 7
        // states including init.
        let trace = r.graph.trace_to(vid);
        assert!(trace.len() <= 7, "trace length {}", trace.len());
        let last = &r.graph.states[vid as usize];
        assert_eq!(last[2], CS);
        assert_eq!(last[3], CS);
    }
}
