//! Property evaluation and reporting — the checker's TLC-style output.

use super::graph::{format_trace, ExploreResult};
use super::scc::find_starvation;
use super::Model;

/// Verdict for one property.
pub enum PropertyVerdict {
    Holds,
    /// Violated; carries a human-readable counterexample.
    Violated(String),
    /// Not evaluated (e.g. exploration truncated).
    Unknown(String),
}

impl PropertyVerdict {
    pub fn holds(&self) -> bool {
        matches!(self, PropertyVerdict::Holds)
    }

    pub fn symbol(&self) -> &'static str {
        match self {
            PropertyVerdict::Holds => "PASS",
            PropertyVerdict::Violated(_) => "FAIL",
            PropertyVerdict::Unknown(_) => "????",
        }
    }
}

impl std::fmt::Display for PropertyVerdict {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PropertyVerdict::Holds => write!(f, "PASS"),
            PropertyVerdict::Violated(t) => write!(f, "FAIL\n{t}"),
            PropertyVerdict::Unknown(why) => write!(f, "UNKNOWN ({why})"),
        }
    }
}

/// Full battery results for one model configuration (one row of the E8
/// table).
pub struct CheckReport {
    pub model: &'static str,
    pub states: usize,
    pub truncated: bool,
    pub mutual_exclusion: PropertyVerdict,
    pub deadlock_free: PropertyVerdict,
    pub starvation_free: PropertyVerdict,
    pub dead_and_livelock_free: PropertyVerdict,
}

impl CheckReport {
    pub fn all_safety_and_liveness_hold(&self) -> bool {
        self.mutual_exclusion.holds()
            && self.deadlock_free.holds()
            && self.starvation_free.holds()
            && self.dead_and_livelock_free.holds()
    }
}

impl std::fmt::Display for CheckReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "model {:<14} states {:>9}{}",
            self.model,
            self.states,
            if self.truncated { " (TRUNCATED)" } else { "" }
        )?;
        writeln!(f, "  MutualExclusion      {}", self.mutual_exclusion.symbol())?;
        writeln!(f, "  DeadlockFree         {}", self.deadlock_free.symbol())?;
        writeln!(f, "  StarvationFree       {}", self.starvation_free.symbol())?;
        writeln!(
            f,
            "  DeadAndLivelockFree  {}",
            self.dead_and_livelock_free.symbol()
        )
    }
}

/// Evaluate the paper's property battery over an explored graph.
pub fn evaluate<M: Model>(model: &M, explored: &ExploreResult<M::State>) -> CheckReport {
    let g = &explored.graph;

    let mutual_exclusion = match explored.me_violation {
        None => PropertyVerdict::Holds,
        Some(sid) => PropertyVerdict::Violated(format!(
            "two processes in the critical section; shortest trace:\n{}",
            format_trace(model, g, sid)
        )),
    };

    let deadlock_free = if explored.deadlocks.is_empty() {
        PropertyVerdict::Holds
    } else {
        let sid = explored.deadlocks[0];
        PropertyVerdict::Violated(format!(
            "deadlocked state (no enabled transition); trace:\n{}",
            format_trace(model, g, sid)
        ))
    };

    let (starvation_free, dead_and_livelock_free) = if explored.truncated {
        (
            PropertyVerdict::Unknown("state space truncated".into()),
            PropertyVerdict::Unknown("state space truncated".into()),
        )
    } else {
        let (starved, livelock) = find_starvation(model, g);
        let sf = if let Some(s) = starved.first() {
            PropertyVerdict::Violated(format!(
                "process p{} can wait forever (fair SCC of {} states; witness state {}); \
                 prefix trace:\n{}",
                s.pid + 1,
                s.scc_size,
                s.witness,
                format_trace(model, g, s.witness)
            ))
        } else {
            PropertyVerdict::Holds
        };
        let dlf = if livelock {
            PropertyVerdict::Violated(
                "fair cycle where some process always wants the CS but none ever enters".into(),
            )
        } else {
            PropertyVerdict::Holds
        };
        (sf, dlf)
    };

    CheckReport {
        model: model.name(),
        states: g.states.len(),
        truncated: explored.truncated,
        mutual_exclusion,
        deadlock_free,
        starvation_free,
        dead_and_livelock_free,
    }
}
