//! Breadth-first reachable-state exploration.
//!
//! Builds the full reachable graph (states, labeled edges, BFS parent
//! pointers for counterexample traces). Mutual exclusion is checked
//! inline during the search so a violation is reported with the
//! *shortest* trace, like TLC does.

use std::collections::HashMap;

use super::Model;

/// Dense id of a reachable state.
pub type StateId = u32;

/// The reachable portion of a model's state graph.
pub struct StateGraph<S> {
    /// States by dense id (BFS discovery order; initial states first).
    pub states: Vec<S>,
    /// Outgoing edges: `(pid, destination)` per source state.
    pub edges: Vec<Vec<(u8, StateId)>>,
    /// BFS tree: `(parent, pid-that-moved)`; `None` for initial states.
    pub parent: Vec<Option<(StateId, u8)>>,
}

impl<S> StateGraph<S> {
    /// Path of `(pid, state)` steps from an initial state to `to`
    /// (inclusive; the initial state carries a dummy pid 0xFF).
    pub fn trace_to(&self, to: StateId) -> Vec<(u8, StateId)> {
        let mut path = vec![];
        let mut cur = to;
        loop {
            match self.parent[cur as usize] {
                Some((p, pid)) => {
                    path.push((pid, cur));
                    cur = p;
                }
                None => {
                    path.push((0xFF, cur));
                    break;
                }
            }
        }
        path.reverse();
        path
    }
}

/// Outcome of an exploration.
pub struct ExploreResult<S> {
    pub graph: StateGraph<S>,
    /// First mutual-exclusion violation, if any.
    pub me_violation: Option<StateId>,
    /// States with no outgoing transition (deadlocks).
    pub deadlocks: Vec<StateId>,
    /// True when the search stopped at `max_states` (verdicts are then
    /// only valid for the explored prefix).
    pub truncated: bool,
}

/// BFS from every initial state; stops early only on state-space
/// explosion past `max_states`.
pub fn explore<M: Model>(model: &M, max_states: usize) -> ExploreResult<M::State> {
    let nproc = model.procs();
    assert!(nproc <= u8::MAX as usize);
    let mut index: HashMap<M::State, StateId> = HashMap::new();
    let mut states: Vec<M::State> = vec![];
    let mut edges: Vec<Vec<(u8, StateId)>> = vec![];
    let mut parent: Vec<Option<(StateId, u8)>> = vec![];
    let mut me_violation = None;
    let mut deadlocks = vec![];
    let mut truncated = false;

    let intern = |s: M::State,
                      from: Option<(StateId, u8)>,
                      states: &mut Vec<M::State>,
                      edges: &mut Vec<Vec<(u8, StateId)>>,
                      parent: &mut Vec<Option<(StateId, u8)>>,
                      index: &mut HashMap<M::State, StateId>|
     -> (StateId, bool) {
        if let Some(&id) = index.get(&s) {
            return (id, false);
        }
        let id = states.len() as StateId;
        index.insert(s.clone(), id);
        states.push(s);
        edges.push(vec![]);
        parent.push(from);
        (id, true)
    };

    let mut frontier: Vec<StateId> = vec![];
    for init in model.initials() {
        let (id, fresh) = intern(
            init,
            None,
            &mut states,
            &mut edges,
            &mut parent,
            &mut index,
        );
        if fresh {
            frontier.push(id);
        }
    }

    let mut head = 0usize;
    while head < frontier.len() {
        let sid = frontier[head];
        head += 1;

        // Check the mutual-exclusion invariant at discovery time.
        if me_violation.is_none() {
            let s = &states[sid as usize];
            let in_cs = (0..nproc).filter(|&p| model.in_cs(s, p)).count();
            if in_cs > 1 {
                me_violation = Some(sid);
            }
        }

        let mut any = false;
        for pid in 0..nproc {
            let next = {
                let s = &states[sid as usize];
                model.step(s, pid)
            };
            if let Some(next) = next {
                any = true;
                let (nid, fresh) = intern(
                    next,
                    Some((sid, pid as u8)),
                    &mut states,
                    &mut edges,
                    &mut parent,
                    &mut index,
                );
                edges[sid as usize].push((pid as u8, nid));
                if fresh {
                    if states.len() > max_states {
                        truncated = true;
                    } else {
                        frontier.push(nid);
                    }
                }
            }
        }
        if !any {
            deadlocks.push(sid);
        }
    }

    ExploreResult {
        graph: StateGraph {
            states,
            edges,
            parent,
        },
        me_violation,
        deadlocks,
        truncated,
    }
}

/// Render a counterexample trace with per-step pc names.
pub fn format_trace<M: Model>(model: &M, g: &StateGraph<M::State>, to: StateId) -> String {
    let mut out = String::new();
    for (i, (pid, sid)) in g.trace_to(to).iter().enumerate() {
        let s = &g.states[*sid as usize];
        let pcs: Vec<String> = (0..model.procs())
            .map(|p| format!("p{}:{}", p + 1, model.pc_name(s, p)))
            .collect();
        if *pid == 0xFF {
            out.push_str(&format!("  {i:3}. <init>        [{}]\n", pcs.join(" ")));
        } else {
            out.push_str(&format!(
                "  {i:3}. p{} moved   [{}]\n",
                pid + 1,
                pcs.join(" ")
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two-counter toy model: each process increments its counter mod 3.
    struct Toy;
    impl Model for Toy {
        type State = [u8; 2];
        fn initials(&self) -> Vec<[u8; 2]> {
            vec![[0, 0]]
        }
        fn procs(&self) -> usize {
            2
        }
        fn step(&self, s: &[u8; 2], pid: usize) -> Option<[u8; 2]> {
            let mut n = *s;
            n[pid] = (n[pid] + 1) % 3;
            Some(n)
        }
        fn in_cs(&self, s: &[u8; 2], pid: usize) -> bool {
            s[pid] == 2
        }
        fn wants_cs(&self, s: &[u8; 2], pid: usize) -> bool {
            s[pid] == 1
        }
        fn pc_name(&self, s: &[u8; 2], pid: usize) -> String {
            format!("{}", s[pid])
        }
        fn name(&self) -> &'static str {
            "toy"
        }
    }

    #[test]
    fn explores_full_product_space() {
        let r = explore(&Toy, 1 << 16);
        assert_eq!(r.graph.states.len(), 9); // 3 × 3
        assert!(!r.truncated);
        assert!(r.deadlocks.is_empty());
        // Both in "cs" (2,2) is reachable — the toy violates ME.
        assert!(r.me_violation.is_some());
    }

    #[test]
    fn trace_reaches_violation() {
        let r = explore(&Toy, 1 << 16);
        let vid = r.me_violation.unwrap();
        let trace = r.graph.trace_to(vid);
        // Shortest path to (2,2) is 4 steps + init.
        assert_eq!(trace.len(), 5);
        assert_eq!(r.graph.states[vid as usize], [2, 2]);
        let txt = format_trace(&Toy, &r.graph, vid);
        assert!(txt.contains("<init>"));
    }

    #[test]
    fn truncation_reported() {
        let r = explore(&Toy, 4);
        assert!(r.truncated);
    }
}
