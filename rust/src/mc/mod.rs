//! Explicit-state model checker (systems S6/S7 in DESIGN.md).
//!
//! The paper validates its design by model-checking a TLA+ specification
//! translated from the PlusCal algorithm in its Appendix A. This module
//! is the in-repo equivalent: [`models::qplock_spec`] transcribes that
//! PlusCal text label-for-label into a finite transition system, and the
//! checker verifies the same properties the paper states:
//!
//! * `MutualExclusion` — invariant over all reachable states;
//! * deadlock freedom — every reachable state has a successor;
//! * `StarvationFree` (`enter ~> cs` per process) and
//!   `DeadAndLivelockFree` — via strongly-connected-component analysis
//!   of the reachable graph under **weak fairness** (see [`scc`]);
//! * `MutualExclusion` *failure* for the naive mixed-atomics lock
//!   ([`models::naive_spec`]) whose remote CAS is split into its
//!   NIC-executed read and write halves — the checker finds the Table-1
//!   interleaving mechanically and reports the trace.
//!
//! The liveness analysis is SCC-granular: a violation is reported when a
//! reachable SCC admits a weakly-fair infinite run in which some process
//! is forever past its `enter` label but never at `cs`. This is sound
//! (reported violations are real); for cycles that weave *around* `cs`
//! states inside an SCC that also contains them it is conservative in
//! the passing direction — the configurations checked here match the
//! verdicts of TLC on the paper's spec.

pub mod graph;
pub mod models;
pub mod props;
pub mod scc;

pub use graph::{ExploreResult, StateGraph};
pub use props::{CheckReport, PropertyVerdict};

/// A finite-state transition system: `P` processes, each taking atomic
/// steps (one PlusCal label = one step).
pub trait Model {
    /// Packed state representation. Must be small: the checker stores
    /// millions of them.
    type State: Clone + Eq + std::hash::Hash;

    /// All initial states (TLA+ specs often have several, e.g. the
    /// paper's `victim ∈ {1, 2}`).
    fn initials(&self) -> Vec<Self::State>;

    /// Number of processes.
    fn procs(&self) -> usize;

    /// Execute one atomic step of `pid` in `s`. `None` when `pid` is
    /// blocked (an `await` whose condition is false, or a busy-wait loop
    /// whose exit condition is false *and* whose body would not change
    /// the state — spinning in place is modeled as disabled, which is
    /// exactly TLA+ stuttering).
    fn step(&self, s: &Self::State, pid: usize) -> Option<Self::State>;

    /// Is `pid` inside its critical section in `s`?
    fn in_cs(&self, s: &Self::State, pid: usize) -> bool;

    /// Is `pid` past its `enter` label but not yet in the critical
    /// section (i.e. "wanting")? Drives the starvation-freedom check.
    fn wants_cs(&self, s: &Self::State, pid: usize) -> bool;

    /// Human-readable program counter of `pid` (trace printing).
    fn pc_name(&self, s: &Self::State, pid: usize) -> String;

    /// Model name for reports.
    fn name(&self) -> &'static str;
}

/// Convenience: run the full battery (safety + deadlock + liveness) on a
/// model and produce a [`CheckReport`].
pub fn check_all<M: Model>(model: &M, max_states: usize) -> CheckReport {
    let explored = graph::explore(model, max_states);
    props::evaluate(model, &explored)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mc::models::peterson_spec::PetersonSpec;

    #[test]
    fn check_all_smoke() {
        let m = PetersonSpec;
        let report = check_all(&m, 1 << 20);
        assert!(report.mutual_exclusion.holds());
        assert!(report.deadlock_free.holds());
    }
}
