//! Hand-rolled CLI (clap is not in the vendored registry): flag parsing
//! with `--key value` / `--flag` syntax, subcommand dispatch, and help
//! text. Kept deliberately dependency-free.
//!
//! Parsing is **strict** (PR 10): every subcommand declares its known
//! `--key value` options and boolean `--flags` in [`SPECS`], and
//! [`Args::validate`] rejects unknown options (a typo like `--procss
//! 64` used to run silently at defaults), extra positional tokens
//! (previously smuggled into the flag list as `__extra_positional=…`
//! that no caller ever checked), and a trailing option missing its
//! value (previously demoted to a bare flag, so `get_num` silently
//! returned the default). Number parsing reports humane errors
//! ([`Args::try_num`]) instead of a raw `Debug` panic.

use std::collections::HashMap;

/// Parsed arguments: positional subcommand + `--key value` options +
/// boolean `--flags`, with any extra positionals kept aside for
/// [`Args::validate`] to reject.
pub struct Args {
    pub subcommand: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
    extra: Vec<String>,
}

/// One subcommand's declared CLI surface: the options that take a
/// value and the boolean flags it accepts. The single source of truth
/// for [`Args::validate`] and the per-subcommand usage line.
pub struct Spec {
    pub name: &'static str,
    /// `--key value` options.
    pub opts: &'static [&'static str],
    /// Boolean `--flags`.
    pub flags: &'static [&'static str],
}

/// Known-flags table, one row per subcommand (kept in the dispatch
/// order of `main.rs` / the HELP text).
pub const SPECS: &[Spec] = &[
    Spec {
        name: "run",
        opts: &["algo", "procs", "local", "iters", "millis", "budget", "cs-ns"],
        flags: &["counted"],
    },
    Spec {
        name: "bench",
        opts: &["exp"],
        flags: &["full", "csv"],
    },
    Spec {
        name: "batch",
        opts: &[],
        flags: &["full"],
    },
    Spec {
        name: "rw",
        opts: &[],
        flags: &["full"],
    },
    Spec {
        name: "multi-lock",
        opts: &[
            "locks", "skew", "procs", "nodes", "iters", "millis", "algo", "budget",
        ],
        flags: &["home0", "timed"],
    },
    Spec {
        name: "async",
        opts: &[
            "sim-procs", "threads", "locks", "skew", "nodes", "iters", "millis", "budget",
        ],
        flags: &["timed", "ready"],
    },
    Spec {
        name: "ready",
        opts: &["pending", "releases", "mode"],
        flags: &[],
    },
    Spec {
        name: "exec",
        opts: &["sessions", "pending", "releases", "threads", "mode"],
        flags: &[],
    },
    Spec {
        name: "crash",
        opts: &[
            "sim-procs",
            "threads",
            "locks",
            "skew",
            "iters",
            "crash-prob",
            "zombie-prob",
            "max-crashes",
            "lease-ticks",
            "budget",
        ],
        flags: &[],
    },
    Spec {
        name: "sim",
        opts: &[
            "schedules",
            "steps",
            "seed",
            "procs",
            "locks",
            "nodes",
            "budget",
            "lease-ticks",
            "ring",
            "drain-rounds",
            "crash-prob",
            "zombie-prob",
            "max-crashes",
            "mode",
            "pct-depth",
            "artifact-dir",
            "replay",
        ],
        flags: &[
            "manual-arm",
            "executor-steps",
            "race-detect",
            "differential",
            "shared",
        ],
    },
    Spec {
        name: "lint",
        opts: &["root"],
        flags: &["hb"],
    },
    Spec {
        name: "mc",
        opts: &["model", "procs", "budget", "max-states"],
        flags: &[],
    },
    Spec {
        name: "serve",
        opts: &["locks"],
        flags: &[],
    },
    Spec {
        name: "list",
        opts: &[],
        flags: &[],
    },
    Spec {
        name: "help",
        opts: &[],
        flags: &[],
    },
];

/// The declared surface of `sub`, if it is a known subcommand.
pub fn spec(sub: &str) -> Option<&'static Spec> {
    SPECS.iter().find(|s| s.name == sub)
}

/// One-line usage string for a known subcommand, derived from its
/// [`Spec`] (so it can never drift from what `validate` accepts).
pub fn usage(sub: &str) -> Option<String> {
    let s = spec(sub)?;
    let mut u = format!("usage: qplock {}", s.name);
    for o in s.opts {
        u.push_str(&format!(" [--{o} <v>]"));
    }
    for f in s.flags {
        u.push_str(&format!(" [--{f}]"));
    }
    Some(u)
}

/// A rejected command line, with enough context to say why humanely.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CliError {
    UnknownSubcommand(String),
    /// `--key` (with or without a value) that the subcommand does not
    /// declare.
    UnknownOption { subcommand: String, option: String },
    /// A declared `--key value` option with no value token after it.
    MissingValue { subcommand: String, option: String },
    /// A declared boolean `--flag` that was handed a value.
    FlagWithValue {
        subcommand: String,
        flag: String,
        value: String,
    },
    /// A positional token after the subcommand.
    ExtraPositional { subcommand: String, token: String },
    /// An option value that failed to parse as the expected number.
    BadNumber {
        option: String,
        value: String,
        reason: String,
    },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownSubcommand(s) => write!(f, "unknown subcommand '{s}'"),
            CliError::UnknownOption { subcommand, option } => {
                write!(f, "'{subcommand}' does not take --{option}")
            }
            CliError::MissingValue { subcommand, option } => {
                write!(f, "'{subcommand}': --{option} requires a value")
            }
            CliError::FlagWithValue {
                subcommand,
                flag,
                value,
            } => write!(
                f,
                "'{subcommand}': --{flag} is a flag and takes no value (got '{value}')"
            ),
            CliError::ExtraPositional { subcommand, token } => {
                write!(f, "'{subcommand}': unexpected positional argument '{token}'")
            }
            CliError::BadNumber {
                option,
                value,
                reason,
            } => write!(f, "invalid value '{value}' for --{option}: {reason}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    /// Tokens starting with `--` take the following token as a value
    /// unless it also starts with `--` or is absent (then it is a
    /// flag). Lenient by construction — [`Args::validate`] applies the
    /// per-subcommand [`SPECS`] strictness.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().peekable();
        let mut subcommand = None;
        let mut opts = HashMap::new();
        let mut flags = vec![];
        let mut extra = vec![];
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        opts.insert(key.to_string(), it.next().unwrap());
                    }
                    _ => flags.push(key.to_string()),
                }
            } else if subcommand.is_none() {
                subcommand = Some(tok);
            } else {
                extra.push(tok);
            }
        }
        Args {
            subcommand,
            opts,
            flags,
            extra,
        }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Check the parsed line against its subcommand's declared surface
    /// ([`SPECS`]): unknown subcommand, unknown `--option`, a declared
    /// option left without a value (the bare-flag demotion that used
    /// to make `get_num` silently return its default), a boolean flag
    /// handed a value, and extra positional tokens are all errors. A
    /// bare `qplock` (no subcommand) is valid — it prints help.
    pub fn validate(&self) -> Result<(), CliError> {
        let Some(sub) = self.subcommand.as_deref() else {
            return Ok(());
        };
        let Some(spec) = spec(sub) else {
            return Err(CliError::UnknownSubcommand(sub.to_string()));
        };
        for (key, value) in &self.opts {
            if spec.opts.iter().any(|o| o == key) {
                continue;
            }
            if spec.flags.iter().any(|f| f == key) {
                return Err(CliError::FlagWithValue {
                    subcommand: sub.to_string(),
                    flag: key.clone(),
                    value: value.clone(),
                });
            }
            return Err(CliError::UnknownOption {
                subcommand: sub.to_string(),
                option: key.clone(),
            });
        }
        for key in &self.flags {
            if spec.flags.iter().any(|f| f == key) {
                continue;
            }
            if spec.opts.iter().any(|o| o == key) {
                return Err(CliError::MissingValue {
                    subcommand: sub.to_string(),
                    option: key.clone(),
                });
            }
            return Err(CliError::UnknownOption {
                subcommand: sub.to_string(),
                option: key.clone(),
            });
        }
        if let Some(tok) = self.extra.first() {
            return Err(CliError::ExtraPositional {
                subcommand: sub.to_string(),
                token: tok.clone(),
            });
        }
        Ok(())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parse an option as `T`, with a default when absent. Malformed
    /// input is a [`CliError::BadNumber`] carrying the option name,
    /// the offending token, and the parser's own reason.
    pub fn try_num<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| CliError::BadNumber {
                option: name.to_string(),
                value: s.to_string(),
                reason: format!("{e}"),
            }),
        }
    }

    /// [`Args::try_num`] for the CLI surface: on malformed input,
    /// print the humane error and exit non-zero (no panic, no
    /// backtrace — this is user input, not a program bug).
    pub fn get_num<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        self.try_num(name, default).unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(2);
        })
    }
}

pub const HELP: &str = "\
qplock — asymmetric mutual exclusion for RDMA (paper reproduction)

USAGE:
  qplock <subcommand> [options]

SUBCOMMANDS:
  run     run a lock workload and print the measurement report
            --algo <name>      lock algorithm (default qplock)
            --procs <n>        total processes (default 8)
            --local <n>        processes on the lock's home node (default procs/2)
            --iters <n>        cycles per process (default 1000)
            --millis <ms>      run for a duration instead of iters
            --budget <n>       qplock/cohort budget (default 8)
            --cs-ns <ns>       critical-section busy work (default 0)
            --counted          zero-latency op-count mode
  bench   run experiments (EXPERIMENTS.md E1..E15)
            --exp <id|all>     experiment id (default all)
            --full             full scale (default quick)
            --csv              also print CSV
  batch   doorbell-batching smoke: the E15 ablation (batch on/off x
          NIC congestion x lock count) plus a pass/fail headline — a
          signalled remote handoff must ring fewer doorbells batched
          than unbatched (exit non-zero otherwise)
            --full             full scale (default quick)
  rw      shared/exclusive smoke: the E14 read-ratio sweep (reader
          crowds vs a draining writer) plus a pass/fail headline —
          shared mode must scale read throughput without starving
          writers, with zero per-mode ME violations (exit non-zero
          otherwise)
            --full             full scale (default quick)
  multi-lock
          closed-loop sweep over a sharded multi-lock table: each
          process draws keys Zipfian over K named locks through a
          per-process handle cache
            --locks <K>        named locks in the table (default 10000)
            --skew <s>         Zipf skew, 0 = uniform (default 0.99)
            --procs <n>        processes, round-robin over nodes (default 6)
            --nodes <n>        cluster nodes (default 3)
            --iters <n>        cycles per process (default 2000)
            --millis <ms>      run for a duration instead of iters
            --algo <name>      lock algorithm (default qplock)
            --budget <n>       qplock/cohort budget (default 8)
            --home0            home every lock on node 0 (default: hash-routed)
            --timed            calibrated-latency mode (default counted)
  async   poll-multiplexed sweep: many simulated processes per OS
          thread, each driving poll-based acquisitions over K named
          locks through a session (no thread parked per process)
            --sim-procs <n>    simulated processes (default 64)
            --threads <t>      OS threads to multiplex onto (default 4)
            --locks <K>        named locks in the table (default 100)
            --skew <s>         Zipf skew, 0 = uniform (default 0.99)
            --nodes <n>        cluster nodes (default 3)
            --iters <n>        cycles per simulated process (default 200)
            --millis <ms>      run for a duration instead of iters
            --budget <n>       qplock budget (default 8)
            --timed            calibrated-latency mode (default counted)
            --ready            event-driven scheduler: sessions consume
                               their wakeup rings instead of scanning
                               every pending acquisition per step
  ready   ready-list wakeup probe: K waiters parked on held locks,
          single releases, scan-mode vs ready-mode poll cost (the
          E12 scenario)
            --pending <K>      parked in-flight acquisitions (default 10000)
            --releases <n>     single releases to measure (default 50)
            --mode <m>         both|scan|ready (default both)
  exec    work-stealing executor probe: many ready-mode sessions run
          as futures on a multi-threaded executor with every fallback
          sweep disabled — wakeup tokens alone must complete both
          waiter classes, budget-parked cohort waiters and
          Peterson-engaged leaders (the E12b scenario)
            --sessions <n>     waiter sessions, one task each (default 4)
            --pending <K>      parked waiters per session (default 1000)
            --releases <n>     measured releases per session (default 50)
            --threads <t>      executor worker threads (default 2)
            --mode <m>         both|budget|peterson (default both)
  crash   fault-injection run over lease-enabled qplock: kill/stall
          simulated processes at the four protocol points (holding,
          enqueued, mid-handoff, armed) while the lease sweeper
          revokes, fences, and repairs around them (the E13 scenario;
          exits non-zero on any oracle violation or wedged survivor)
            --sim-procs <n>    simulated processes (default 64)
            --threads <t>      OS threads to multiplex onto (default 4)
            --locks <K>        named locks in the table (default 100)
            --skew <s>         Zipf skew (default 0.9)
            --iters <n>        cycles per surviving process (default 12)
            --crash-prob <p>   per-eligible-step injection prob (default 0.005)
            --zombie-prob <p>  stall-instead-of-kill fraction (default 0.5)
            --max-crashes <n>  injection cap (default 16)
            --lease-ticks <n>  lease term in clock ticks (default 400)
            --budget <n>       qplock budget (default 8)
  sim     deterministic schedule explorer over the real stack (see
          TESTING.md): seeded interleavings of poll/arm/ready/release/
          sweep/clock steps with crash injection, ME/progress/lease
          oracles, automatic shrinking of failing schedules to minimal
          replayable JSONL artifacts (exit non-zero on violation)
            --schedules <n>    seeds to explore (default 200)
            --steps <n>        random-phase steps per schedule (default 400)
            --seed <s>         base seed (default 1)
            --procs <n>        simulated actors (default 4)
            --locks <K>        named locks (default 3)
            --nodes <n>        cluster nodes (default 2)
            --lease-ticks <n>  lease term (default 64)
            --ring <n>         session wakeup-ring arming bound (default 8)
            --drain-rounds <n> progress-oracle round bound (default 5000)
            --crash-prob <p>   per-step injection prob (default 0.02)
            --zombie-prob <p>  stall-instead-of-kill fraction (default 0.5)
            --max-crashes <n>  injection cap per schedule (default 2)
            --mode <m>         uniform|pct|churn scheduler (default uniform)
            --pct-depth <n>    priority-change points in pct mode (default 3)
            --manual-arm       wakeup arming as its own scheduled step
            --executor-steps   schedule the executor-shaped steps too
                               (steal, migrate, waker-drop, spurious)
            --shared           grow the step alphabet with shared-mode
                               (reader) submissions; the ME oracle
                               checks per-mode overlap rules
            --race-detect      vector-clock race detector: fail any
                               cross-actor conflict no declared
                               OrderEdge orders (also QPLOCK_RACE_DETECT=1)
            --artifact-dir <d> where failing traces go (default
                               target/sim-artifacts)
            --replay <file>    re-execute a recorded artifact instead
            --differential     emit the handle-level lockstep trace and
                               exit (diff against poll_model_check.py
                               --trace; --seed/--steps apply)
  lint    static verb-contract pass over the crate sources: every
          protocol-word access must go through the contract-tagged
          accessors (rdma::contract), offsets must match the
          word-ownership registry, RMW lanes must never mix, and
          Class::Local paths must stay NIC-silent (exit non-zero on
          any finding; same pass as the verb_lint binary)
            --root <dir>       source tree to lint (default this crate's src/)
            --hb               run the ordering-contract pass instead:
                               every declared OrderEdge's two sides in
                               program order, SeqCst gate flags, and
                               sanctioned gate writers (Layer 5)
  mc      model-check a spec (paper Appendix A)
            --model <name>     qplock|peterson|naive|spin (default qplock)
            --procs <n>        processes (default 3)
            --budget <n>       InitialBudget (default 1)
            --max-states <n>   state-space cap (default 2^23)
  serve   demo the named-lock service router
            --locks <n>        number of named locks (default 4)
  list    list lock algorithms and experiments
  help    this text
";

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = args("bench --exp e3 --full");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get("exp"), Some("e3"));
        assert!(a.flag("full"));
        assert!(!a.flag("csv"));
        assert_eq!(a.validate(), Ok(()));
    }

    #[test]
    fn numeric_defaults_and_parsing() {
        let a = args("run --procs 12");
        assert_eq!(a.get_num("procs", 8u32), 12);
        assert_eq!(a.get_num("budget", 8u64), 8);
    }

    #[test]
    fn malformed_number_is_a_humane_error() {
        // Regression: `get_num` used to panic with the raw `Debug`
        // rendering of the parse error. The error now names the
        // option, quotes the token, and carries the parser's reason.
        let a = args("run --procs twelve");
        let e = a.try_num::<u32>("procs", 8).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("--procs"), "names the option: {msg}");
        assert!(msg.contains("'twelve'"), "quotes the token: {msg}");
        assert!(!msg.contains("ParseIntError"), "no Debug guts: {msg}");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = args("run --counted --full");
        assert!(a.flag("counted"));
        assert!(a.flag("full"));
    }

    #[test]
    fn unknown_option_is_rejected() {
        // Regression: `--procss 64` (typo) used to run at defaults.
        let a = args("run --procss 64");
        assert_eq!(
            a.validate(),
            Err(CliError::UnknownOption {
                subcommand: "run".into(),
                option: "procss".into(),
            })
        );
        // Same for a typo'd bare flag.
        let a = args("bench --ful");
        assert_eq!(
            a.validate(),
            Err(CliError::UnknownOption {
                subcommand: "bench".into(),
                option: "ful".into(),
            })
        );
    }

    #[test]
    fn trailing_option_without_value_is_rejected() {
        // Regression: a trailing `--procs` was demoted to a bare flag,
        // so `get_num("procs", …)` silently returned the default.
        let a = args("run --procs");
        assert_eq!(
            a.validate(),
            Err(CliError::MissingValue {
                subcommand: "run".into(),
                option: "procs".into(),
            })
        );
        // An option directly followed by another `--token` is the
        // same demotion mid-line.
        let a = args("run --procs --counted");
        assert_eq!(
            a.validate(),
            Err(CliError::MissingValue {
                subcommand: "run".into(),
                option: "procs".into(),
            })
        );
    }

    #[test]
    fn flag_handed_a_value_is_rejected() {
        let a = args("run --counted 5");
        assert_eq!(
            a.validate(),
            Err(CliError::FlagWithValue {
                subcommand: "run".into(),
                flag: "counted".into(),
                value: "5".into(),
            })
        );
    }

    #[test]
    fn extra_positional_is_rejected() {
        // Regression: extra positionals were parked as
        // `__extra_positional=…` pseudo-flags that nothing checked.
        let a = args("run qplock");
        assert_eq!(
            a.validate(),
            Err(CliError::ExtraPositional {
                subcommand: "run".into(),
                token: "qplock".into(),
            })
        );
        assert!(!a.flag("__extra_positional=qplock"));
    }

    #[test]
    fn unknown_subcommand_is_rejected() {
        let a = args("frobnicate --fast");
        assert_eq!(
            a.validate(),
            Err(CliError::UnknownSubcommand("frobnicate".into()))
        );
        // No subcommand at all is fine: it prints help.
        assert_eq!(args("").validate(), Ok(()));
    }

    #[test]
    fn every_spec_accepts_its_own_full_surface() {
        // The table is self-consistent: a line exercising every
        // declared option and flag of each subcommand validates.
        for s in SPECS {
            let mut line = s.name.to_string();
            for o in s.opts {
                line.push_str(&format!(" --{o} 1"));
            }
            for f in s.flags {
                line.push_str(&format!(" --{f}"));
            }
            assert_eq!(args(&line).validate(), Ok(()), "spec '{}'", s.name);
        }
    }

    #[test]
    fn usage_lines_derive_from_the_spec() {
        let u = usage("lint").unwrap();
        assert_eq!(u, "usage: qplock lint [--root <v>] [--hb]");
        assert!(usage("frobnicate").is_none());
    }
}
