//! Hand-rolled CLI (clap is not in the vendored registry): flag parsing
//! with `--key value` / `--flag` syntax, subcommand dispatch, and help
//! text. Kept deliberately dependency-free.

use std::collections::HashMap;

/// Parsed arguments: positional subcommand + `--key value` options +
/// boolean `--flags`.
pub struct Args {
    pub subcommand: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    /// Tokens starting with `--` take the following token as a value
    /// unless it also starts with `--` or is absent (then it is a flag).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().peekable();
        let mut subcommand = None;
        let mut opts = HashMap::new();
        let mut flags = vec![];
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        opts.insert(key.to_string(), it.next().unwrap());
                    }
                    _ => flags.push(key.to_string()),
                }
            } else if subcommand.is_none() {
                subcommand = Some(tok);
            } else {
                // Extra positional: treat as error-worthy garbage; keep
                // it visible for the caller.
                flags.push(format!("__extra_positional={tok}"));
            }
        }
        Args {
            subcommand,
            opts,
            flags,
        }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parse an option as `T`, with a default. Panics with a clear
    /// message on malformed input (CLI surface, not library).
    pub fn get_num<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        match self.get(name) {
            None => default,
            Some(s) => s
                .parse()
                .unwrap_or_else(|e| panic!("--{name} {s}: {e:?}")),
        }
    }
}

pub const HELP: &str = "\
qplock — asymmetric mutual exclusion for RDMA (paper reproduction)

USAGE:
  qplock <subcommand> [options]

SUBCOMMANDS:
  run     run a lock workload and print the measurement report
            --algo <name>      lock algorithm (default qplock)
            --procs <n>        total processes (default 8)
            --local <n>        processes on the lock's home node (default procs/2)
            --iters <n>        cycles per process (default 1000)
            --millis <ms>      run for a duration instead of iters
            --budget <n>       qplock/cohort budget (default 8)
            --cs-ns <ns>       critical-section busy work (default 0)
            --counted          zero-latency op-count mode
  bench   run experiments (EXPERIMENTS.md E1..E15)
            --exp <id|all>     experiment id (default all)
            --full             full scale (default quick)
            --csv              also print CSV
  batch   doorbell-batching smoke: the E15 ablation (batch on/off x
          NIC congestion x lock count) plus a pass/fail headline — a
          signalled remote handoff must ring fewer doorbells batched
          than unbatched (exit non-zero otherwise)
            --full             full scale (default quick)
  multi-lock
          closed-loop sweep over a sharded multi-lock table: each
          process draws keys Zipfian over K named locks through a
          per-process handle cache
            --locks <K>        named locks in the table (default 10000)
            --skew <s>         Zipf skew, 0 = uniform (default 0.99)
            --procs <n>        processes, round-robin over nodes (default 6)
            --nodes <n>        cluster nodes (default 3)
            --iters <n>        cycles per process (default 2000)
            --millis <ms>      run for a duration instead of iters
            --algo <name>      lock algorithm (default qplock)
            --budget <n>       qplock/cohort budget (default 8)
            --home0            home every lock on node 0 (default: hash-routed)
            --timed            calibrated-latency mode (default counted)
  async   poll-multiplexed sweep: many simulated processes per OS
          thread, each driving poll-based acquisitions over K named
          locks through a session (no thread parked per process)
            --sim-procs <n>    simulated processes (default 64)
            --threads <t>      OS threads to multiplex onto (default 4)
            --locks <K>        named locks in the table (default 100)
            --skew <s>         Zipf skew, 0 = uniform (default 0.99)
            --nodes <n>        cluster nodes (default 3)
            --iters <n>        cycles per simulated process (default 200)
            --millis <ms>      run for a duration instead of iters
            --budget <n>       qplock budget (default 8)
            --timed            calibrated-latency mode (default counted)
            --ready            event-driven scheduler: sessions consume
                               their wakeup rings instead of scanning
                               every pending acquisition per step
  ready   ready-list wakeup probe: K waiters parked on held locks,
          single releases, scan-mode vs ready-mode poll cost (the
          E12 scenario)
            --pending <K>      parked in-flight acquisitions (default 10000)
            --releases <n>     single releases to measure (default 50)
            --mode <m>         both|scan|ready (default both)
  exec    work-stealing executor probe: many ready-mode sessions run
          as futures on a multi-threaded executor with every fallback
          sweep disabled — wakeup tokens alone must complete both
          waiter classes, budget-parked cohort waiters and
          Peterson-engaged leaders (the E12b scenario)
            --sessions <n>     waiter sessions, one task each (default 4)
            --pending <K>      parked waiters per session (default 1000)
            --releases <n>     measured releases per session (default 50)
            --threads <t>      executor worker threads (default 2)
            --mode <m>         both|budget|peterson (default both)
  crash   fault-injection run over lease-enabled qplock: kill/stall
          simulated processes at the four protocol points (holding,
          enqueued, mid-handoff, armed) while the lease sweeper
          revokes, fences, and repairs around them (the E13 scenario;
          exits non-zero on any oracle violation or wedged survivor)
            --sim-procs <n>    simulated processes (default 64)
            --threads <t>      OS threads to multiplex onto (default 4)
            --locks <K>        named locks in the table (default 100)
            --skew <s>         Zipf skew (default 0.9)
            --iters <n>        cycles per surviving process (default 12)
            --crash-prob <p>   per-eligible-step injection prob (default 0.005)
            --zombie-prob <p>  stall-instead-of-kill fraction (default 0.5)
            --max-crashes <n>  injection cap (default 16)
            --lease-ticks <n>  lease term in clock ticks (default 400)
            --budget <n>       qplock budget (default 8)
  sim     deterministic schedule explorer over the real stack (see
          TESTING.md): seeded interleavings of poll/arm/ready/release/
          sweep/clock steps with crash injection, ME/progress/lease
          oracles, automatic shrinking of failing schedules to minimal
          replayable JSONL artifacts (exit non-zero on violation)
            --schedules <n>    seeds to explore (default 200)
            --steps <n>        random-phase steps per schedule (default 400)
            --seed <s>         base seed (default 1)
            --procs <n>        simulated actors (default 4)
            --locks <K>        named locks (default 3)
            --nodes <n>        cluster nodes (default 2)
            --lease-ticks <n>  lease term (default 64)
            --ring <n>         session wakeup-ring arming bound (default 8)
            --drain-rounds <n> progress-oracle round bound (default 5000)
            --crash-prob <p>   per-step injection prob (default 0.02)
            --zombie-prob <p>  stall-instead-of-kill fraction (default 0.5)
            --max-crashes <n>  injection cap per schedule (default 2)
            --mode <m>         uniform|pct|churn scheduler (default uniform)
            --pct-depth <n>    priority-change points in pct mode (default 3)
            --manual-arm       wakeup arming as its own scheduled step
            --executor-steps   schedule the executor-shaped steps too
                               (steal, migrate, waker-drop, spurious)
            --race-detect      vector-clock race detector: fail any
                               cross-actor conflict no declared
                               OrderEdge orders (also QPLOCK_RACE_DETECT=1)
            --artifact-dir <d> where failing traces go (default
                               target/sim-artifacts)
            --replay <file>    re-execute a recorded artifact instead
            --differential     emit the handle-level lockstep trace and
                               exit (diff against poll_model_check.py
                               --trace; --seed/--steps apply)
  lint    static verb-contract pass over the crate sources: every
          protocol-word access must go through the contract-tagged
          accessors (rdma::contract), offsets must match the
          word-ownership registry, RMW lanes must never mix, and
          Class::Local paths must stay NIC-silent (exit non-zero on
          any finding; same pass as the verb_lint binary)
            --root <dir>       source tree to lint (default this crate's src/)
            --hb               run the ordering-contract pass instead:
                               every declared OrderEdge's two sides in
                               program order, SeqCst gate flags, and
                               sanctioned gate writers (Layer 5)
  mc      model-check a spec (paper Appendix A)
            --model <name>     qplock|peterson|naive|spin (default qplock)
            --procs <n>        processes (default 3)
            --budget <n>       InitialBudget (default 1)
  serve   demo the named-lock service router
            --locks <n>        number of named locks (default 4)
  list    list lock algorithms and experiments
  help    this text
";

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = args("bench --exp e3 --full");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get("exp"), Some("e3"));
        assert!(a.flag("full"));
        assert!(!a.flag("csv"));
    }

    #[test]
    fn numeric_defaults_and_parsing() {
        let a = args("run --procs 12");
        assert_eq!(a.get_num("procs", 8u32), 12);
        assert_eq!(a.get_num("budget", 8u64), 8);
    }

    #[test]
    #[should_panic]
    fn malformed_number_panics() {
        let a = args("run --procs twelve");
        let _ = a.get_num("procs", 8u32);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = args("run --counted --full");
        assert!(a.flag("counted"));
        assert!(a.flag("full"));
    }
}
