//! Experiment implementations E1–E11 (see EXPERIMENTS.md roster).
//!
//! Each experiment regenerates one table/figure of the evaluation:
//! E1 reproduces the paper's Table 1; E2 verifies the §3.1 analytical
//! operation-count claims; E3–E7 are the standard RDMA-lock evaluation
//! suite (throughput scaling, locality mix, budget/fairness, latency,
//! loopback congestion); E8 reproduces the TLA+ verification battery;
//! E9 is the end-to-end parameter-server run over the PJRT runtime;
//! E10 sweeps the sharded multi-lock table; E11 compares
//! thread-per-process against poll-multiplexed acquisition; E12
//! measures the scan-vs-ready-list poll cost at large parked-waiter
//! counts, plus the work-stealing executor fleet with the fallback
//! sweep disabled (one million parked waiters at full scale); E14
//! sweeps shared-mode reader–writer traffic (read-ratio × skew × K)
//! against the exclusive-only and RPC-server baselines; E15
//! ablates doorbell batching on the signalled remote-handoff path
//! (batch on/off × NIC congestion × lock count).
//!
//! Every experiment runs at two scales: `Quick` (cargo bench / CI) and
//! `Full` (the numbers recorded in EXPERIMENTS.md).

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::table::Table;
use crate::coordinator::{
    exec_crash_probe, exec_probe, ready_list_probe, run_crash_workload, run_multi_lock_workload,
    run_multiplexed_workload, run_workload, Cluster, CrashPlan, CsWork, ExecCrashConfig,
    ExecProbeConfig, LockService, PollMode, RunResult, Workload,
};
use crate::locks::{make_lock, AcqPhase, ArmOutcome, Class, WakeupReg};
use crate::mc::{self, models};
use crate::rdma::{
    AtomicityMode, DomainConfig, LatencyModel, RdmaDomain, TimeMode, WakeupRing,
};

/// Experiment scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Small sweeps, short durations — smoke/CI.
    Quick,
    /// The EXPERIMENTS.md configuration.
    Full,
}

/// Output of one experiment.
pub struct ExpOutput {
    pub id: &'static str,
    pub tables: Vec<Table>,
    pub notes: Vec<String>,
}

impl std::fmt::Display for ExpOutput {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "######## experiment {} ########", self.id)?;
        for t in &self.tables {
            writeln!(f, "{t}")?;
        }
        for n in &self.notes {
            writeln!(f, "note: {n}")?;
        }
        Ok(())
    }
}

/// Registry of all experiments: `(id, what it regenerates)`.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("e1", "paper Table 1: atomicity of 8B local x remote accesses"),
    ("e2", "paper §3.1 claims: RDMA ops per acquisition"),
    ("e3", "throughput vs process count, all algorithms"),
    ("e4", "throughput vs local:remote mix"),
    ("e5", "qplock budget sweep: fairness vs throughput"),
    ("e6", "acquisition latency percentiles per class"),
    ("e7", "loopback congestion ablation"),
    ("e8", "model-checking battery (paper Appendix A)"),
    ("e9", "end-to-end parameter server over the native engine"),
    (
        "e10",
        "multi-lock: Zipfian sweep over the sharded lock service (K x skew x placement)",
    ),
    (
        "e11",
        "async: thread-per-process vs poll-multiplexed acquisition (K x skew)",
    ),
    (
        "e12",
        "ready-list wakeups: scan vs ready poll cost at K parked waiters",
    ),
    (
        "e13",
        "crash recovery: fault injection x class mix under qplock leases",
    ),
    (
        "e14",
        "read-write: shared-mode reader scaling vs exclusive-only and RPC baselines \
         (read-ratio x skew x K)",
    ),
    (
        "e15",
        "doorbell ablation: chained WQEs per signalled remote handoff (batch x congestion x K)",
    ),
];

/// Run one experiment by id.
pub fn run_experiment(id: &str, scale: Scale) -> ExpOutput {
    match id {
        "e1" => e1_atomicity(scale),
        "e2" => e2_op_counts(scale),
        "e3" => e3_throughput(scale),
        "e4" => e4_mix(scale),
        "e5" => e5_budget(scale),
        "e6" => e6_latency(scale),
        "e7" => e7_loopback(scale),
        "e8" => e8_model_check(scale),
        "e9" => e9_param_server(scale),
        "e10" => e10_multi_lock(scale),
        "e11" => e11_multiplexed(scale),
        "e12" => e12_ready_wakeups(scale),
        "e13" => e13_crash_recovery(scale),
        "e14" => e14_read_write(scale),
        "e15" => e15_doorbell_ablation(scale),
        other => panic!("unknown experiment '{other}'"),
    }
}

// ---------------------------------------------------------------- helpers

fn timed_domain(latency: LatencyModel) -> DomainConfig {
    DomainConfig {
        latency,
        time_mode: TimeMode::Timed,
        atomicity: AtomicityMode::NicSerialized,
        hazard_ns: 0,
        pad_lines: true,
        batching: false,
    }
}

struct TimedRun {
    result: RunResult,
}

fn timed_run(
    algo: &str,
    nprocs: u32,
    nlocal: u32,
    dur: Duration,
    budget: u64,
    cfg: DomainConfig,
) -> TimedRun {
    let cluster = Cluster::new(2, 1 << 20, cfg);
    let lock = make_lock(algo, &cluster.domain, 0, nprocs, budget);
    let procs = cluster.spread_procs(nprocs, nlocal, 0);
    let wl = Workload::timed(dur, CsWork::None);
    let result = run_workload(&cluster.domain, &lock, &procs, &wl);
    assert_eq!(result.violations, 0, "{algo} violated mutual exclusion");
    TimedRun { result }
}

fn fmt_thr(r: &RunResult) -> String {
    format!("{:.0}", r.throughput())
}

fn fmt_netns(r: &RunResult) -> String {
    let net: u64 = r.procs.iter().map(|p| p.ops.net_ns).sum();
    format!("{:.0}", net as f64 / r.total_acquisitions().max(1) as f64)
}

// ------------------------------------------------------------------- E1

/// Reproduce paper Table 1 by *measurement*: for each (local op, remote
/// op) pair, run a directed race and report whether atomicity was
/// preserved, under both NIC-serialized (commodity) and global
/// atomicity.
fn e1_atomicity(scale: Scale) -> ExpOutput {
    let iters = match scale {
        Scale::Quick => 40,
        Scale::Full => 200,
    };

    // Probe: local mutator fires mid-window of a remote CAS. Atomicity
    // violation signals (0 => atomic):
    //  * local Write vs remote RMW — the local store is *lost* (final
    //    value is the CAS's swap even though the store happened inside
    //    the CAS);
    //  * local RMW vs remote RMW — *both* CASes of 0→tag report success.
    fn lost_updates(mode: AtomicityMode, iters: u32, local_is_rmw: bool) -> u32 {
        use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
        let d = RdmaDomain::new(
            2,
            256,
            DomainConfig::counted()
                .with_atomicity(mode)
                .with_hazard_ns(1_500_000),
        );
        let home = d.endpoint(0);
        let a = home.alloc(1);
        let mut violations = 0;
        for _ in 0..iters {
            home.write(a, 0);
            let started = Arc::new(AtomicBool::new(false));
            let s2 = Arc::clone(&started);
            let remote_ep = d.endpoint(1);
            let aa = a;
            let t = std::thread::spawn(move || {
                s2.store(true, SeqCst);
                remote_ep.r_cas(aa, 0, 111)
            });
            while !started.load(SeqCst) {
                std::thread::yield_now();
            }
            crate::util::spin::spin_wait_ns(300_000);
            if local_is_rmw {
                let local_won = home.cas(a, 0, 222) == 0;
                let remote_won = t.join().unwrap() == 0;
                if local_won && remote_won {
                    violations += 1; // two winners: RMWs not atomic
                }
            } else {
                home.write(a, 222);
                t.join().unwrap();
                if home.read(a) == 111 {
                    violations += 1; // store silently overwritten
                }
            }
        }
        violations
    }

    let mut t = Table::new(
        "E1: atomicity of 8-byte local x remote accesses (paper Table 1)",
        &[
            "local-op",
            "vs rRead",
            "vs rWrite",
            "vs rCAS (commodity)",
            "vs rCAS (global)",
            "paper",
        ],
    );
    // Read/Write rows vs rRead/rWrite are atomic by construction at 8B
    // (single-register accesses); measured rCAS cells:
    let w_comm = lost_updates(AtomicityMode::NicSerialized, iters, false);
    let w_glob = lost_updates(AtomicityMode::Global, iters, false);
    let c_comm = lost_updates(AtomicityMode::NicSerialized, iters, true);
    let c_glob = lost_updates(AtomicityMode::Global, iters, true);
    let yn = |lost: u32| if lost == 0 { "Yes".to_string() } else { format!("No ({lost} lost)") };

    t.row(&[
        "Read".into(),
        "Yes".into(),
        "Yes".into(),
        "Yes".into(),
        "Yes".into(),
        "Y/Y/Y".into(),
    ]);
    t.row(&[
        "Write".into(),
        "Yes".into(),
        "Yes".into(),
        yn(w_comm),
        yn(w_glob),
        "Y/Y/N".into(),
    ]);
    t.row(&[
        "RMW".into(),
        "Yes".into(),
        "Yes".into(),
        yn(c_comm),
        yn(c_glob),
        "Y/Y/N".into(),
    ]);

    let mut notes = vec![
        "commodity = AtomicityMode::NicSerialized (remote RMW atomic only among remote RMWs)"
            .into(),
        "paper column reads: atomic vs rRead / rWrite / rCAS".into(),
    ];
    if w_comm == 0 || c_comm == 0 {
        notes.push("WARNING: expected lost updates under commodity mode, got none".into());
    }
    ExpOutput {
        id: "e1",
        tables: vec![t],
        notes,
    }
}

// ------------------------------------------------------------------- E2

/// Verify §3.1: local processes need 0 RDMA ops; a lone remote process
/// acquires with a single rCAS (plus Peterson engagement) and releases
/// with at most rCAS + rWrite; queued remotes add one rWrite.
fn e2_op_counts(_scale: Scale) -> ExpOutput {
    let algos = [
        "qplock",
        "rdma-mcs",
        "spin-rcas",
        "cohort-tas",
        "rpc-server",
        "filter",
        "bakery",
    ];
    let mut t = Table::new(
        "E2: remote verbs per acquisition (lone process; counted mode)",
        &[
            "algo",
            "lone-local rdma",
            "lone-local loopback",
            "lone-remote rCAS",
            "lone-remote rRead",
            "lone-remote rWrite",
        ],
    );
    for algo in algos {
        // Lone local process.
        let d = RdmaDomain::new(2, 1 << 16, DomainConfig::counted());
        let lock = make_lock(algo, &d, 0, 8, 8);
        let iters = 100u64;
        let ep = d.endpoint(0);
        let m_local = Arc::clone(&ep.metrics);
        let mut h = lock.handle(ep, 0);
        for _ in 0..iters {
            h.lock();
            h.unlock();
        }
        let sl = m_local.snapshot();

        // Lone remote process.
        let ep = d.endpoint(1);
        let m_rem = Arc::clone(&ep.metrics);
        let mut h = lock.handle(ep, 1);
        for _ in 0..iters {
            h.lock();
            h.unlock();
        }
        let sr = m_rem.snapshot();

        let per = |x: u64| format!("{:.2}", x as f64 / iters as f64);
        t.row(&[
            algo.into(),
            per(sl.remote_total()),
            per(sl.loopback),
            per(sr.remote_cas),
            per(sr.remote_read),
            per(sr.remote_write),
        ]);
    }
    // Fabric transactions on the handoff path: the §3.1 analysis
    // counts verbs; the doorbell layer counts how many times those
    // verbs touch the wire independently. One row per issue mode,
    // same deterministic signalled-handoff probe as E15.
    let mut t2 = Table::new(
        "E2b: fabric transactions per signalled remote handoff (qplock, counted mode)",
        &[
            "issue",
            "handoffs",
            "WQEs/handoff",
            "doorbells/handoff",
            "fabric-ns/handoff",
        ],
    );
    for batch in [false, true] {
        let s = handoff_probe(batch, false, 1, 100);
        t2.row(&[
            (if batch { "batched" } else { "unbatched" }).into(),
            s.handoffs.to_string(),
            s.per(s.release_wqes),
            s.per(s.release_doorbells),
            s.per(s.release_net_ns),
        ]);
    }
    ExpOutput {
        id: "e2",
        tables: vec![t, t2],
        notes: vec![
            "paper claims for qplock: lone-local rdma = 0; lone-remote = 1 rCAS + \
             Peterson engagement (1 rWrite + 1 rRead) on acquire, 1 rCAS on release"
                .into(),
            "rpc-server lone-local shows 0 rdma (shared-memory fast path) but every \
             op costs a server round trip"
                .into(),
            "E2b: a signalled remote handoff issues the same WQE stream either way; \
             batching chains it behind one doorbell (the §Perf entry), unbatched \
             issue rings one doorbell per WQE — see E15 for the full ablation"
                .into(),
        ],
    }
}

// ------------------------------------------------------------------- E3

fn e3_throughput(scale: Scale) -> ExpOutput {
    let (proc_counts, dur): (&[u32], Duration) = match scale {
        Scale::Quick => (&[2, 8], Duration::from_millis(80)),
        Scale::Full => (&[2, 4, 8, 16], Duration::from_millis(300)),
    };
    let algos = [
        "qplock",
        "rdma-mcs",
        "spin-rcas",
        "cohort-tas",
        "rpc-server",
        "filter",
        "bakery",
    ];
    let mut t = Table::new(
        "E3: aggregate throughput (acq/s), 50/50 local:remote, empty CS",
        &["algo/procs", "2", "4", "8", "16"],
    );
    let mut net = Table::new(
        "E3b: modeled fabric ns per acquisition",
        &["algo/procs", "2", "4", "8", "16"],
    );
    for algo in algos {
        let mut cells = vec![algo.to_string()];
        let mut ncells = vec![algo.to_string()];
        for &n in &[2u32, 4, 8, 16] {
            if !proc_counts.contains(&n) {
                cells.push("-".into());
                ncells.push("-".into());
                continue;
            }
            let r = timed_run(algo, n, n / 2, dur, 8, timed_domain(LatencyModel::calibrated()));
            cells.push(fmt_thr(&r.result));
            ncells.push(fmt_netns(&r.result));
        }
        t.row(&cells);
        net.row(&ncells);
    }
    ExpOutput {
        id: "e3",
        tables: vec![t, net],
        notes: vec![
            "expected shape: qplock ≥ rdma-mcs > cohort-tas > spin-rcas ≫ filter/bakery; \
             rpc bounded by server round trips"
                .into(),
            "single-core host: wall throughput is scheduler-multiplexed; fabric ns/acq \
             (E3b) is the scheduling-independent cost"
                .into(),
        ],
    }
}

// ------------------------------------------------------------------- E4

fn e4_mix(scale: Scale) -> ExpOutput {
    let (fracs, dur): (&[u32], Duration) = match scale {
        Scale::Quick => (&[0, 50, 100], Duration::from_millis(80)),
        Scale::Full => (&[0, 25, 50, 75, 100], Duration::from_millis(300)),
    };
    let nprocs = 8u32;
    let algos = ["qplock", "rdma-mcs", "spin-rcas", "rpc-server"];
    let mut t = Table::new(
        "E4: throughput (acq/s) vs %local processes, 8 procs",
        &["algo/%local", "0", "25", "50", "75", "100"],
    );
    for algo in algos {
        let mut cells = vec![algo.to_string()];
        for &f in &[0u32, 25, 50, 75, 100] {
            if !fracs.contains(&f) {
                cells.push("-".into());
                continue;
            }
            let nlocal = nprocs * f / 100;
            let r = timed_run(
                algo,
                nprocs,
                nlocal,
                dur,
                8,
                timed_domain(LatencyModel::calibrated()),
            );
            cells.push(fmt_thr(&r.result));
        }
        t.row(&cells);
    }
    ExpOutput {
        id: "e4",
        tables: vec![t],
        notes: vec![
            "expected shape: qplock's advantage grows with %local (locals never touch \
             the NIC); class-blind locks are flat-to-worse as loopback replaces wire"
                .into(),
        ],
    }
}

// ------------------------------------------------------------------- E5

fn e5_budget(scale: Scale) -> ExpOutput {
    let (budgets, dur): (&[u64], Duration) = match scale {
        Scale::Quick => (&[1, 8], Duration::from_millis(80)),
        Scale::Full => (&[1, 2, 4, 8, 16, 64], Duration::from_millis(300)),
    };
    let mut t = Table::new(
        "E5: qplock budget sweep (4 local + 4 remote procs, 2µs CS)",
        &["budget", "thr acq/s", "jain", "local acq%", "fabric ns/acq"],
    );
    for &b in budgets {
        // A small CS payload keeps both cohorts continuously backlogged
        // (with an empty CS the cheap local class simply outruns the
        // remotes and the budget never engages — the budget bounds
        // consecutive handoffs *while the other cohort waits*).
        let cluster = Cluster::new(2, 1 << 20, timed_domain(LatencyModel::calibrated()));
        let lock = make_lock("qplock", &cluster.domain, 0, 8, b);
        let procs = cluster.spread_procs(8, 4, 0);
        let wl = Workload::timed(dur, CsWork::SpinNs(2_000));
        let r = run_workload(&cluster.domain, &lock, &procs, &wl);
        assert_eq!(r.violations, 0);
        let (l, rm) = r.class_split();
        t.row(&[
            b.to_string(),
            fmt_thr(&r),
            format!("{:.3}", r.jain()),
            format!("{:.1}", 100.0 * l as f64 / (l + rm).max(1) as f64),
            fmt_netns(&r),
        ]);
    }
    ExpOutput {
        id: "e5",
        tables: vec![t],
        notes: vec![
            "expected shape: small budgets force frequent global handoffs — class \
             split near 50/50 and jain near 1 at some throughput cost; large budgets \
             amortize the Peterson handoff and favor the cheaper (local) class"
                .into(),
        ],
    }
}

// ------------------------------------------------------------------- E6

fn e6_latency(scale: Scale) -> ExpOutput {
    let dur = match scale {
        Scale::Quick => Duration::from_millis(80),
        Scale::Full => Duration::from_millis(400),
    };
    let algos = ["qplock", "rdma-mcs", "spin-rcas", "rpc-server"];
    let mut t = Table::new(
        "E6: acquire latency by class (ns), 4 local + 4 remote procs",
        &[
            "algo", "L p50", "L p95", "L p99", "R p50", "R p95", "R p99",
        ],
    );
    for algo in algos {
        let r = timed_run(algo, 8, 4, dur, 8, timed_domain(LatencyModel::calibrated()));
        let hl = r.result.acquire_hist(Some(Class::Local));
        let hr = r.result.acquire_hist(Some(Class::Remote));
        t.row(&[
            algo.into(),
            hl.p50().to_string(),
            hl.p95().to_string(),
            hl.p99().to_string(),
            hr.p50().to_string(),
            hr.p95().to_string(),
            hr.p99().to_string(),
        ]);
    }
    ExpOutput {
        id: "e6",
        tables: vec![t],
        notes: vec![
            "expected shape: qplock's local-class latency ≪ its remote-class latency \
             and ≪ any class-blind lock's local latency (which pays loopback)"
                .into(),
        ],
    }
}

// ------------------------------------------------------------------- E7

fn e7_loopback(scale: Scale) -> ExpOutput {
    let dur = match scale {
        Scale::Quick => Duration::from_millis(80),
        Scale::Full => Duration::from_millis(300),
    };
    // Local-heavy: 6 local + 2 remote. Congestion knob on/off.
    let mut t = Table::new(
        "E7: loopback congestion ablation (6 local + 2 remote procs)",
        &["algo", "congestion", "thr acq/s", "peak NIC queue", "fabric ns/acq"],
    );
    for algo in ["qplock", "spin-rcas"] {
        for &(label, cong) in &[("off", 0u64), ("on", 2_000u64)] {
            let mut lat = LatencyModel::calibrated();
            lat.congestion_ns_per_op = cong;
            lat.nic_capacity = 2;
            let cluster = Cluster::new(2, 1 << 20, timed_domain(lat));
            let lock = make_lock(algo, &cluster.domain, 0, 8, 8);
            let procs = cluster.spread_procs(8, 6, 0);
            let wl = Workload::timed(dur, CsWork::None);
            let r = run_workload(&cluster.domain, &lock, &procs, &wl);
            assert_eq!(r.violations, 0);
            let peak = cluster.domain.node(0).nic.metrics.peak_inflight
                .load(std::sync::atomic::Ordering::Relaxed);
            let net: u64 = r.procs.iter().map(|p| p.ops.net_ns).sum();
            t.row(&[
                algo.into(),
                label.into(),
                fmt_thr(&r),
                peak.to_string(),
                format!("{:.0}", net as f64 / r.total_acquisitions().max(1) as f64),
            ]);
        }
    }
    ExpOutput {
        id: "e7",
        tables: vec![t],
        notes: vec![
            "expected shape: spin-rcas floods the home NIC via loopback and degrades \
             further when congestion pricing is on; qplock's local majority never \
             enters the NIC, so it is insensitive to the knob"
                .into(),
        ],
    }
}

// ------------------------------------------------------------------- E8

fn e8_model_check(scale: Scale) -> ExpOutput {
    let mut t = Table::new(
        "E8: model checking (paper Appendix A battery)",
        &[
            "model", "config", "states", "ME", "deadlock-free", "starvation-free",
            "livelock-free", "ms",
        ],
    );
    let mut run = |name: &str, cfg: String, report_ms: (mc::CheckReport, u128)| {
        let (r, ms) = report_ms;
        t.row(&[
            name.into(),
            cfg,
            r.states.to_string(),
            r.mutual_exclusion.symbol().into(),
            r.deadlock_free.symbol().into(),
            r.starvation_free.symbol().into(),
            r.dead_and_livelock_free.symbol().into(),
            ms.to_string(),
        ]);
    };
    let check = |m: &dyn Fn() -> mc::CheckReport| {
        let t0 = Instant::now();
        let r = m();
        (r, t0.elapsed().as_millis())
    };

    run(
        "peterson-2p",
        "n=2".into(),
        check(&|| mc::check_all(&models::peterson_spec::PetersonSpec, 1 << 20)),
    );
    run(
        "qplock",
        "n=2 B=1".into(),
        check(&|| mc::check_all(&models::qplock_spec::QpSpec::new(2, 1), 1 << 22)),
    );
    run(
        "qplock",
        "n=3 B=1".into(),
        check(&|| mc::check_all(&models::qplock_spec::QpSpec::new(3, 1), 1 << 22)),
    );
    run(
        "qplock",
        "n=3 B=2".into(),
        check(&|| mc::check_all(&models::qplock_spec::QpSpec::new(3, 2), 1 << 22)),
    );
    if scale == Scale::Full {
        run(
            "qplock",
            "n=4 B=2".into(),
            check(&|| mc::check_all(&models::qplock_spec::QpSpec::new(4, 2), 1 << 23)),
        );
    }
    run(
        "naive-mixed",
        "n=2".into(),
        check(&|| mc::check_all(&models::naive_spec::NaiveSpec, 1 << 16)),
    );
    run(
        "spin-rcas",
        "n=2".into(),
        check(&|| mc::check_all(&models::spin_spec::SpinSpec::new(2), 1 << 16)),
    );

    ExpOutput {
        id: "e8",
        tables: vec![t],
        notes: vec![
            "expected: qplock PASSes everything (paper's TLC result); naive-mixed \
             FAILs MutualExclusion (Table-1 race, found mechanically); spin-rcas is \
             safe but FAILs StarvationFree"
                .into(),
        ],
    }
}

// ------------------------------------------------------------------- E9

fn e9_param_server(scale: Scale) -> ExpOutput {
    use crate::runtime::{ParamServer, XlaRuntime};
    let steps_per_proc = match scale {
        Scale::Quick => 20u64,
        Scale::Full => 75,
    };
    let rt = XlaRuntime::cpu().expect("compute engine");
    let mut t = Table::new(
        "E9: parameter server, 2 local + 2 remote writers, model step in CS",
        &[
            "lock", "steps", "wall ms", "steps/s", "final metric", "violations",
        ],
    );
    let mut final_metrics = vec![];
    for algo in ["qplock", "spin-rcas", "rpc-server"] {
        let cluster = Cluster::new(2, 1 << 20, timed_domain(LatencyModel::calibrated()));
        let ps = Arc::new(ParamServer::load(&rt, "unused", Default::default()).unwrap());
        let metric = Arc::new(std::sync::Mutex::new(0f32));
        let cs = {
            let ps = Arc::clone(&ps);
            let metric = Arc::clone(&metric);
            CsWork::Callback(Arc::new(move |pid| {
                let (u, v) = ps.synth_factors(0xE9 ^ pid as u64);
                let m = ps.step(&u, &v).expect("model step");
                *metric.lock().unwrap() = m;
            }))
        };
        let lock = make_lock(algo, &cluster.domain, 0, 4, 8);
        let procs = cluster.spread_procs(4, 2, 0);
        let mut wl = Workload::cycles(steps_per_proc);
        wl.cs = cs;
        let r = run_workload(&cluster.domain, &lock, &procs, &wl);
        assert_eq!(r.violations, 0, "{algo}");
        let fm = *metric.lock().unwrap();
        final_metrics.push(fm);
        t.row(&[
            algo.into(),
            r.total_acquisitions().to_string(),
            format!("{:.0}", r.wall.as_secs_f64() * 1e3),
            format!("{:.1}", r.throughput()),
            format!("{fm:.5}"),
            r.violations.to_string(),
        ]);
    }
    ExpOutput {
        id: "e9",
        tables: vec![t],
        notes: vec![
            "all locks converge to the same fixed-point metric (same compute, \
             different coordination cost); steps run the native engine's port of \
             the Pallas/JAX reference kernels — no Python on the request path"
                .into(),
            format!("final metrics across locks: {final_metrics:?}"),
        ],
    }
}

// ------------------------------------------------------------------ E10

/// Multi-lock scenario: K named locks in the sharded [`LockService`],
/// processes drawing keys Zipfian per cycle through per-process handle
/// caches. Sweeps table size × skew × placement and reports per-class
/// verb behavior — the paper's asymmetry claims restated at lock-table
/// scale (ALock / RDMA-lock-management style).
fn e10_multi_lock(scale: Scale) -> ExpOutput {
    let (iters, procs_n) = match scale {
        Scale::Quick => (150u64, 6u32),
        Scale::Full => (1_500, 9),
    };
    // (K, skew, placement): `hash` spreads homes FNV-style over all
    // nodes; `node0` pins every lock's home to node 0 (the local-heavy
    // extreme for processes living there).
    let configs: &[(u32, f64, &str)] = &[
        (1, 0.0, "hash"),
        (100, 0.0, "hash"),
        (100, 0.99, "hash"),
        (100, 0.99, "node0"),
        (10_000, 0.99, "hash"),
    ];
    let mut t = Table::new(
        "E10: multi-lock Zipfian sweep (qplock, 3 nodes, counted mode)",
        &[
            "locks",
            "skew",
            "placement",
            "thr acq/s",
            "local-rdma",
            "rverbs/acq",
            "rank0%",
            "touched",
            "cache-hit%",
            "violations",
        ],
    );
    let mut notes = vec![
        "local-rdma = remote verbs (incl. loopback) issued through handles of \
         locks homed on the issuing process's node — the paper requires exactly 0 \
         for qplock at any table size"
            .into(),
        "rank0% = share of acquisitions landing on the Zipf rank-0 (intended-hottest) \
         lock — ~1/K at skew 0 (the old 'hot%' reported the max per-lock share, an \
         upward-biased extreme at low skew); cache-hit% = handle-cache reuse (misses \
         are one-time descriptor mints)"
            .into(),
    ];
    for &(k, skew, placement) in configs {
        let cluster = Cluster::new(3, 1 << 21, DomainConfig::counted());
        let svc = Arc::new(LockService::new(&cluster.domain, "qplock", 8));
        if placement == "node0" {
            for i in 0..k {
                svc.create_lock(&crate::coordinator::lock_name(i), "qplock", 0, 64, 8)
                    .expect("fresh table");
            }
        }
        let procs = cluster.round_robin_procs(procs_n);
        let wl = Workload::cycles(iters).with_locks(k, skew);
        let r = run_multi_lock_workload(&svc, &procs, &wl);
        assert_eq!(
            r.violations, 0,
            "mutual exclusion violated at K={k} skew={skew}"
        );
        t.row(&[
            k.to_string(),
            format!("{skew:.2}"),
            placement.into(),
            format!("{:.0}", r.throughput()),
            r.local_class_remote_verbs().to_string(),
            format!("{:.2}", r.remote_verbs_per_acq()),
            format!("{:.1}", 100.0 * r.hottest_share()),
            r.locks_touched().to_string(),
            format!("{:.1}", 100.0 * r.cache_hit_rate()),
            r.violations.to_string(),
        ]);
    }
    notes.push(format!(
        "{iters} cycles/process x {procs_n} processes per row; quick scale keeps \
         the 10k-lock row so CI exercises table-scale behavior"
    ));
    ExpOutput {
        id: "e10",
        tables: vec![t],
        notes,
    }
}

// ------------------------------------------------------------------ E11

/// Thread-per-process vs poll-multiplexed acquisition: the same
/// Zipfian multi-lock workload driven (a) by one OS thread per
/// simulated process parked in blocking `lock()` and (b) by a few OS
/// threads round-robining poll-based sessions
/// ([`run_multiplexed_workload`]). The asymmetry property that makes
/// (b) viable — a parked waiter polls its own node's memory, zero
/// remote verbs — is re-asserted per row.
fn e11_multiplexed(scale: Scale) -> ExpOutput {
    let (iters, sims, mux_threads) = match scale {
        Scale::Quick => (50u64, 64u32, 4usize),
        Scale::Full => (400, 256, 8),
    };
    // (K, skew): table size x contention shape.
    let configs: &[(u32, f64)] = &[(100, 0.0), (100, 0.99), (10_000, 0.0), (10_000, 0.99)];
    let mut t = Table::new(
        "E11: thread-per-process vs poll-multiplexed (qplock, 3 nodes, counted mode)",
        &[
            "locks",
            "skew",
            "thr/proc-thread",
            "thr/multiplexed",
            "os-threads",
            "local-rdma",
            "p99 acq ns (mux)",
            "violations",
        ],
    );
    for &(k, skew) in configs {
        let wl = Workload::cycles(iters).with_locks(k, skew);
        let mut thr = vec![];
        let mut local_rdma = 0u64;
        let mut p99 = 0u64;
        let mut violations = 0u64;
        for mode in ["thread-per-process", "multiplexed"] {
            let cluster = Cluster::new(3, 1 << 21, DomainConfig::counted());
            let svc = Arc::new(
                LockService::new(&cluster.domain, "qplock", 8).with_default_max_procs(sims),
            );
            let procs = cluster.round_robin_procs(sims);
            let r = if mode == "multiplexed" {
                run_multiplexed_workload(&svc, &procs, &wl, mux_threads)
            } else {
                run_multi_lock_workload(&svc, &procs, &wl)
            };
            assert_eq!(r.violations, 0, "{mode} violated mutual exclusion");
            thr.push(r.throughput());
            violations += r.violations;
            if mode == "multiplexed" {
                local_rdma = r.local_class_remote_verbs();
                let mut h = crate::stats::Histogram::new();
                for p in &r.procs {
                    h.merge(&p.acquire_ns);
                }
                p99 = h.p99();
            }
        }
        t.row(&[
            k.to_string(),
            format!("{skew:.2}"),
            format!("{:.0}", thr[0]),
            format!("{:.0}", thr[1]),
            format!("{sims}->{mux_threads}"),
            local_rdma.to_string(),
            p99.to_string(),
            violations.to_string(),
        ]);
    }
    ExpOutput {
        id: "e11",
        tables: vec![t],
        notes: vec![
            format!(
                "{sims} simulated processes x {iters} cycles per row; thread-per-process \
                 burns {sims} OS threads, multiplexed drives the same workload on \
                 {mux_threads} (poll-based sessions, round-robin scheduling)"
            ),
            "local-rdma = remote verbs through locally-homed handles in the multiplexed \
             run — polling parked waiters must add zero (paper's local-spin waiting)"
                .into(),
            "acquire latency in multiplexed mode includes multiplexing delay \
             (submit -> held across poll rounds)"
                .into(),
        ],
    }
}

// ------------------------------------------------------------------ E12

/// Scan-mode vs ready-mode poll cost at large in-flight waiter counts:
/// K acquisitions parked in one session (every named lock held by a
/// holder session), single releases, counting the waiter session's
/// handle polls. The ready list turns per-release discovery cost from
/// O(pending) — `poll_all` touching every parked waiter — into
/// O(ready): consume the token the handoff published, poll that one
/// handle. This is what makes the 100k-waiter-per-thread regime
/// affordable.
fn e12_ready_wakeups(scale: Scale) -> ExpOutput {
    let (ks, releases): (&[u32], u32) = match scale {
        Scale::Quick => (&[1_000, 10_000], 20),
        Scale::Full => (&[10_000, 100_000], 100),
    };
    let mut t = Table::new(
        "E12: poll cost at K parked waiters — scan vs ready-list (qplock, counted mode)",
        &[
            "pending",
            "mode",
            "releases",
            "rounds",
            "polls",
            "polls/release",
            "us/release",
        ],
    );
    for &k in ks {
        for (label, mode) in [("scan", PollMode::Scan), ("ready", PollMode::Ready)] {
            let s = ready_list_probe(k, releases, mode);
            t.row(&[
                k.to_string(),
                label.into(),
                s.releases.to_string(),
                s.rounds.to_string(),
                s.handle_polls.to_string(),
                format!("{:.1}", s.polls_per_release()),
                format!("{:.1}", s.wall.as_secs_f64() * 1e6 / s.releases as f64),
            ]);
        }
    }
    // Executor-scaled half: the work-stealing session executor drives
    // many ready-mode sessions at once with every fallback sweep
    // disabled, so the wakeup path alone carries the full population —
    // including the Peterson-engaged leaders that used to need the
    // scan loop. Full scale parks one million waiters.
    let (sessions, per_session, releases2, threads) = match scale {
        Scale::Quick => (4u32, 250u32, 25u32, 2usize),
        Scale::Full => (16, 62_500, 100, 8),
    };
    let mut t2 = Table::new(
        "E12b: executor fleet, fallback sweep disabled — every waiter class on wakeups alone",
        &[
            "total-pending",
            "sessions",
            "threads",
            "waiter-class",
            "releases",
            "polls",
            "polls/release",
            "steals",
            "us/release",
            "wakes",
            "wakes/release",
        ],
    );
    for (label, cross_class) in [("budget-parked", false), ("peterson-leader", true)] {
        let s = exec_probe(ExecProbeConfig {
            sessions,
            pending_per_session: per_session,
            releases_per_session: releases2,
            threads,
            cross_class,
        });
        // Satellite invariant (asserted, not just reported): the board
        // drain coalesces duplicate wakers per pass, so effective wakes
        // can never exceed parks filed — each park's waker is consumed
        // by at most one drain.
        assert!(
            s.exec.wakes <= s.exec.idle_parks,
            "{label}: {} wakes exceed {} idle parks — board drain is firing \
             redundant wakes for one session",
            s.exec.wakes,
            s.exec.idle_parks,
        );
        assert!(s.exec.wakes >= 1, "{label}: sessions never woke from the board");
        t2.row(&[
            s.total_pending.to_string(),
            sessions.to_string(),
            threads.to_string(),
            label.into(),
            s.total_releases.to_string(),
            s.handle_polls.to_string(),
            format!("{:.2}", s.polls_per_release()),
            s.exec.steals.to_string(),
            format!("{:.1}", s.wall.as_secs_f64() * 1e6 / s.total_releases.max(1) as f64),
            s.exec.wakes.to_string(),
            format!("{:.2}", s.exec.wakes as f64 / s.total_releases.max(1) as f64),
        ]);
    }
    ExpOutput {
        id: "e12",
        tables: vec![t, t2],
        notes: vec![
            "scenario: one session holds all K locks, a second session (same node, \
             same cohort) has all K acquisitions parked in WaitBudget; each release \
             hands off to exactly one waiter"
                .into(),
            "expected shape: scan polls/release ≈ K (every parked waiter touched per \
             round); ready polls/release ≈ 1 (the handoff's token names the one \
             ready handle) — per-round work scales with ready count, not pending \
             count"
                .into(),
            "setup polls (parking + arming the waiters) are excluded; ready-mode \
             arming is O(K) once, amortized over the session's lifetime"
                .into(),
            "E12b: waiter sessions run as tasks on the work-stealing executor with \
             sweep_interval 0 — no scan fallback anywhere. budget-parked waiters \
             wake via the passer-written descriptor token; peterson-leader waiters \
             (cross-class, every waiter its cohort's engaged leader) wake via the \
             lock's waker block. polls/release ≈ 1 for both classes is the \
             last-scan-loop-closed acceptance"
                .into(),
            "wakes counts task enqueues that actually happened: the idle board \
             coalesces duplicate wakers per drain pass, so wakes ≤ idle parks is \
             asserted inside the experiment — N board entries for one session fire \
             one wake, not N"
                .into(),
        ],
    }
}

// ------------------------------------------------------------------ E13

/// Crash recovery under fault injection: the E13 roster entry. Runs
/// the multiplexed Zipfian workload over a **lease-enabled** service
/// while a [`CrashPlan`] kills or stalls simulated processes at the
/// four named protocol points (holding, enqueued, mid-handoff,
/// armed-for-wakeup) and the service's sweeper revokes and repairs
/// around them. Sweeps crash rate × class mix; reports revocation and
/// relay counts, the recovery-latency histogram (lease-clock ticks
/// from expiry to completed repair), and the two acceptance headlines:
/// zero mutual-exclusion violations and zero wedged survivors.
fn e13_crash_recovery(scale: Scale) -> ExpOutput {
    // Quick scale IS the acceptance configuration: ≥ 64 procs, ≥ 100
    // locks, crashes forced at all four protocol points.
    let (procs_n, nlocks, iters, max_crashes) = match scale {
        Scale::Quick => (64u32, 100u32, 12u64, 16u32),
        Scale::Full => (128, 1_000, 40, 64),
    };
    // (crash_prob, mix): "mixed" round-robins processes over all nodes
    // (every lock sees both classes); "local" pins all locks to node 0
    // with half the processes there (the local-heavy extreme, where
    // repair of local-class cohorts is CPU-only).
    let configs: &[(f64, &str)] = &[(0.0005, "mixed"), (0.005, "mixed"), (0.005, "local")];
    let mut t = Table::new(
        "E13: crash recovery under fault injection (qplock leases, counted mode)",
        &[
            "crash-p",
            "mix",
            "kills",
            "zombies",
            "points",
            "revoked",
            "relays",
            "fenced-late",
            "rec p50",
            "rec p99",
            "completed",
            "violations",
            "wedged",
        ],
    );
    for &(p, mix) in configs {
        let cluster = Cluster::new(3, 1 << 21, DomainConfig::counted());
        let svc = Arc::new(
            LockService::new(&cluster.domain, "qplock", 8)
                .with_default_max_procs(procs_n)
                .with_lease_ticks(400),
        );
        let procs = if mix == "local" {
            for i in 0..nlocks {
                svc.create_lock(&crate::coordinator::lock_name(i), "qplock", 0, procs_n, 8)
                    .expect("fresh table");
            }
            cluster.spread_procs(procs_n, procs_n / 2, 0)
        } else {
            cluster.round_robin_procs(procs_n)
        };
        let wl = Workload::cycles(iters).with_locks(nlocks, 0.9);
        let plan = CrashPlan::all_points(p, 0.5, max_crashes);
        let r = run_crash_workload(&svc, &procs, &wl, 4, &plan);
        assert_eq!(
            r.violations, 0,
            "mutual exclusion violated across a revoke/fence at p={p} mix={mix}"
        );
        assert!(!r.wedged, "wedged survivors at p={p} mix={mix}");
        t.row(&[
            format!("{p}"),
            mix.into(),
            r.kills.iter().sum::<u64>().to_string(),
            r.zombies.iter().sum::<u64>().to_string(),
            r.points_injected().to_string(),
            r.sweep.fenced.to_string(),
            r.sweep.relayed.to_string(),
            r.fenced_late_writes.to_string(),
            r.sweep.recovery_ticks.p50().to_string(),
            r.sweep.recovery_ticks.p99().to_string(),
            r.completed.to_string(),
            r.violations.to_string(),
            if r.wedged { "yes".into() } else { "no".into() },
        ]);
    }
    // Worker-thread kill (ISSUE 10 satellite): the same crash
    // discipline aimed at the scheduling layer. The E12b fleet shape —
    // reader and writer sessions as executor tasks — loses a worker
    // thread mid-run, and the pool itself is the repair mechanism:
    // queued sessions are stolen, parked ones re-woken by survivors'
    // board drains. Zero lost locks and full completion are asserted.
    let mut wt = Table::new(
        "E13w: worker-thread kill on the session executor (qplock, counted mode)",
        &[
            "sessions",
            "locks",
            "threads",
            "completed",
            "rd-cycles",
            "wr-cycles",
            "kill-at",
            "steals",
            "lost-locks",
        ],
    );
    let wt_cfgs: &[ExecCrashConfig] = match scale {
        Scale::Quick => &[ExecCrashConfig {
            sessions: 12,
            locks: 6,
            cycles: 8,
            threads: 4,
            reader_every: 3,
        }],
        Scale::Full => &[
            ExecCrashConfig {
                sessions: 24,
                locks: 8,
                cycles: 16,
                threads: 4,
                reader_every: 3,
            },
            ExecCrashConfig {
                sessions: 48,
                locks: 12,
                cycles: 16,
                threads: 8,
                reader_every: 2,
            },
        ],
    };
    for &cfg in wt_cfgs {
        let r = exec_crash_probe(cfg);
        assert_eq!(
            r.completed,
            cfg.sessions as u64 * cfg.cycles as u64,
            "cycles lost with the dead worker"
        );
        assert_eq!(r.lost_locks, 0, "a session stranded a hold across the kill");
        assert_eq!(r.exec.worker_kills, 1);
        wt.row(&[
            cfg.sessions.to_string(),
            cfg.locks.to_string(),
            cfg.threads.to_string(),
            r.completed.to_string(),
            r.reader_cycles.to_string(),
            r.writer_cycles.to_string(),
            r.kill_at.to_string(),
            r.exec.steals.to_string(),
            r.lost_locks.to_string(),
        ]);
    }
    ExpOutput {
        id: "e13",
        tables: vec![t, wt],
        notes: vec![
            format!(
                "{procs_n} simulated processes x {iters} cycles over {nlocks} locks (skew \
                 0.9), lease term 400 ticks, sweeper thread ticking + sweeping continuously; \
                 first injection at each protocol point is forced (and a zombie), so every \
                 repair shape is exercised in every row"
            ),
            "revoked = expired leases fenced; relays = owed handoffs passed around dead \
             owners; fenced-late = zombie wake-side writes rejected by the fence (each one \
             a prevented double release); rec p50/p99 = lease-clock ticks from expiry to \
             completed repair"
                .into(),
            "invariants: zero oracle violations and zero wedged survivors in every row — \
             asserted, not just reported"
                .into(),
            "E13w kills a *scheduler worker* instead of a process: sessions are healthy, \
             the work-stealing pool is the recovery mechanism, and zero lost locks plus \
             full completion (readers included) are asserted per row"
                .into(),
        ],
    }
}

// ------------------------------------------------------------------ E14

/// Result of one E14 configuration run.
struct RwStats {
    reads: u64,
    writes: u64,
    /// Scheduler rounds until every actor finished its op quota — the
    /// concurrency proxy: overlapping readers finish in fewer rounds.
    rounds: u64,
    /// Rounds from submit to admission, readers (0 = fast path).
    read_wait: crate::stats::Histogram,
    /// Rounds from submit to admission, writers.
    write_wait: crate::stats::Histogram,
    /// Peak readers observed inside one lock's critical section.
    max_read_overlap: u32,
    /// Per-mode overlap oracle violations (readers never overlap a
    /// writer; writers overlap nothing).
    violations: u64,
    /// NIC ops across all nodes attributable to this run.
    fabric_ops: u64,
}

/// Per-lock per-mode overlap oracle: tracks who is inside each critical
/// section from the *caller's* view (between admission and release).
struct RwOracle {
    lk: Vec<(u32, bool)>, // (readers inside, writer inside)
    violations: u64,
    max_read_overlap: u32,
}

impl RwOracle {
    fn new(k: u32) -> RwOracle {
        RwOracle {
            lk: vec![(0, false); k as usize],
            violations: 0,
            max_read_overlap: 0,
        }
    }

    fn enter(&mut self, li: usize, write: bool) {
        let (r, w) = &mut self.lk[li];
        if write {
            if *w || *r > 0 {
                self.violations += 1; // writer overlapped someone
            }
            *w = true;
        } else {
            if *w {
                self.violations += 1; // reader overlapped a writer
            }
            *r += 1;
            self.max_read_overlap = self.max_read_overlap.max(*r);
        }
    }

    fn exit(&mut self, li: usize, write: bool) {
        let (r, w) = &mut self.lk[li];
        if write {
            *w = false;
        } else {
            *r -= 1;
        }
    }
}

/// Deterministic shared-mode headline: `n` readers on three nodes all
/// hold one qplock concurrently on the fast path; a writer then
/// enqueues, closes the batch, drains the generation, and acquires
/// exclusively; after its release the generation reopens for readers.
/// Returns `(readers held concurrently, writer drain polls)`.
fn rw_headline(n: u32) -> (u32, u64) {
    let cluster = Cluster::new(3, 1 << 18, DomainConfig::counted());
    let svc = Arc::new(
        LockService::new(&cluster.domain, "qplock", 8).with_default_max_procs(n + 1),
    );
    let mut readers: Vec<_> = (0..n).map(|i| svc.session((i % 3) as u16)).collect();
    let mut held = 0u32;
    for r in readers.iter_mut() {
        if r.submit_shared("rw-headline").expect("headline submit").is_held() {
            held += 1;
        }
    }
    let mut w = svc.session(0);
    assert!(
        w.submit("rw-headline").expect("headline writer").is_pending(),
        "writer must queue behind the open generation"
    );
    assert!(w.poll_all().is_empty(), "writer admitted while readers hold");
    for r in readers.iter_mut() {
        r.release("rw-headline").expect("reader release");
    }
    let mut polls = 0u64;
    while !w.poll_all().iter().any(|x| x == "rw-headline") {
        polls += 1;
        assert!(polls < 64, "writer never drained the generation");
    }
    w.release("rw-headline").expect("writer release");
    // The writer's release reopens the generation: a fresh reader gets
    // the fast path again.
    assert!(
        readers[0].submit_shared("rw-headline").expect("reopen").is_held(),
        "generation failed to reopen after the writer"
    );
    readers[0].release("rw-headline").expect("reopen release");
    (held, polls)
}

/// Round-robin reader–writer probe over the sharded lock service:
/// `procs` single-op-in-flight actors (sessions spread over 3 nodes)
/// each complete `iters` operations, drawing the lock Zipfian(`skew`)
/// over `k` locks and the mode Bernoulli(`read_ratio`). `shared`
/// selects `submit_shared` for reads; off, the identical draw sequence
/// runs exclusive-only (the baseline). Held sections span one extra
/// round so admissions can overlap observably. Counted mode, one OS
/// thread: bit-deterministic.
fn rw_probe(shared: bool, procs: u32, k: u32, iters: u64, read_ratio: f64, skew: f64) -> RwStats {
    enum St {
        Idle,
        Pending { li: usize, name: String, write: bool, since: u64 },
        Held { li: usize, name: String, write: bool, left: u32 },
    }
    struct Actor {
        sess: crate::coordinator::HandleCache,
        rng: crate::util::prng::Prng,
        st: St,
        left_ops: u64,
        done: bool,
    }
    let cluster = Cluster::new(3, 1 << 21, DomainConfig::counted());
    let svc = Arc::new(
        LockService::new(&cluster.domain, "qplock", 8).with_default_max_procs(procs),
    );
    let zipf = crate::util::prng::Zipf::new(k, skew);
    let mut actors: Vec<Actor> = (0..procs)
        .map(|i| Actor {
            sess: svc.session((i % 3) as u16),
            // Same seeds in every E14 configuration, so the op streams
            // are identical across shared / exclusive / RPC runs.
            rng: crate::util::prng::Prng::seed_from(0xE14_0000 + i as u64 * 7919),
            st: St::Idle,
            left_ops: iters,
            done: false,
        })
        .collect();
    let mut oracle = RwOracle::new(k);
    let mut s = RwStats {
        reads: 0,
        writes: 0,
        rounds: 0,
        read_wait: crate::stats::Histogram::new(),
        write_wait: crate::stats::Histogram::new(),
        max_read_overlap: 0,
        violations: 0,
        fabric_ops: 0,
    };
    let (ops0, _) = nic_totals(&cluster.domain);
    let mut rounds = 0u64;
    while actors.iter().any(|a| !a.done) {
        rounds += 1;
        assert!(rounds < 1 << 20, "e14 wedged at rr={read_ratio} skew={skew} K={k}");
        for a in actors.iter_mut() {
            if a.done {
                continue;
            }
            match &mut a.st {
                St::Idle => {
                    if a.left_ops == 0 {
                        a.done = true;
                        continue;
                    }
                    let li = zipf.sample(&mut a.rng) as usize;
                    let write = !a.rng.chance(read_ratio);
                    let name = crate::coordinator::lock_name(li as u32);
                    let poll = if !write && shared {
                        a.sess.submit_shared(&name)
                    } else {
                        a.sess.submit(&name)
                    }
                    .expect("e14 submit");
                    if poll.is_held() {
                        oracle.enter(li, write);
                        if write {
                            s.write_wait.record(0);
                        } else {
                            s.read_wait.record(0);
                        }
                        a.st = St::Held { li, name, write, left: 1 };
                    } else {
                        a.st = St::Pending { li, name, write, since: rounds };
                    }
                }
                St::Pending { li, name, write, since } => {
                    let (li, name, write, since) = (*li, name.clone(), *write, *since);
                    if a.sess.poll_all().iter().any(|n| *n == name) {
                        oracle.enter(li, write);
                        if write {
                            s.write_wait.record(rounds - since);
                        } else {
                            s.read_wait.record(rounds - since);
                        }
                        a.st = St::Held { li, name, write, left: 1 };
                    }
                }
                St::Held { left, .. } if *left > 0 => *left -= 1,
                St::Held { li, name, write, .. } => {
                    let (li, name, write) = (*li, name.clone(), *write);
                    oracle.exit(li, write);
                    a.sess.release(&name).expect("e14 release");
                    if write {
                        s.writes += 1;
                    } else {
                        s.reads += 1;
                    }
                    a.left_ops -= 1;
                    a.st = St::Idle;
                }
            }
        }
    }
    let (ops1, _) = nic_totals(&cluster.domain);
    s.rounds = rounds;
    s.max_read_overlap = oracle.max_read_overlap;
    s.violations = oracle.violations;
    s.fabric_ops = ops1 - ops0;
    s
}

/// RPC-server reader baseline: the same actor seeds and draw order as
/// [`rw_probe`], but every op — read or write — is a blocking
/// lock/unlock round trip through the home-node server. Ops are
/// closed-loop (one per actor turn, nothing held across turns), so
/// reads can never overlap: the column the shared rows are measured
/// against.
fn rpc_probe(procs: u32, k: u32, iters: u64, read_ratio: f64, skew: f64) -> RwStats {
    let d = RdmaDomain::new(3, 1 << 21, DomainConfig::counted());
    let locks: Vec<_> = (0..k)
        .map(|i| make_lock("rpc-server", &d, (i % 3) as u16, procs, 8))
        .collect();
    let mut handles: Vec<Vec<_>> = (0..procs)
        .map(|p| {
            locks
                .iter()
                .map(|l| l.handle(d.endpoint((p % 3) as u16), p))
                .collect()
        })
        .collect();
    let zipf = crate::util::prng::Zipf::new(k, skew);
    let mut s = RwStats {
        reads: 0,
        writes: 0,
        rounds: procs as u64 * iters, // one completed op per actor turn
        read_wait: crate::stats::Histogram::new(),
        write_wait: crate::stats::Histogram::new(),
        max_read_overlap: 1,
        violations: 0,
        fabric_ops: 0,
    };
    let (ops0, _) = nic_totals(&d);
    for p in 0..procs as usize {
        let mut rng = crate::util::prng::Prng::seed_from(0xE14_0000 + p as u64 * 7919);
        for _ in 0..iters {
            let li = zipf.sample(&mut rng) as usize;
            let write = !rng.chance(read_ratio);
            handles[p][li].lock();
            handles[p][li].unlock();
            if write {
                s.writes += 1;
            } else {
                s.reads += 1;
            }
        }
    }
    let (ops1, _) = nic_totals(&d);
    s.fabric_ops = ops1 - ops0;
    s
}

/// E14: shared-mode reader scaling (read-ratio × skew × K) against the
/// exclusive-only qplock baseline and the RPC lock-server baseline,
/// with the per-mode overlap oracle asserted in every cell.
fn e14_read_write(scale: Scale) -> ExpOutput {
    let (procs, k, iters, combos): (u32, u32, u64, &[(f64, f64)]) = match scale {
        Scale::Quick => (12, 16, 6, &[(0.5, 0.9), (0.95, 0.9)]),
        Scale::Full => (
            48,
            100,
            20,
            &[
                (0.5, 0.5),
                (0.9, 0.5),
                (0.99, 0.5),
                (0.5, 0.99),
                (0.9, 0.99),
                (0.99, 0.99),
            ],
        ),
    };
    let headline_n = procs.min(8);
    let (held, drain_polls) = rw_headline(headline_n);
    assert_eq!(held, headline_n, "every reader must share the open generation");
    let mut ht = Table::new(
        "E14a: shared-mode headline — one qplock, N readers, one writer (counted mode)",
        &["readers", "held-concurrently", "writer-drain-polls", "reopened"],
    );
    ht.row(&[
        headline_n.to_string(),
        held.to_string(),
        drain_polls.to_string(),
        "yes".into(),
    ]);

    let mut t = Table::new(
        "E14b: reader-writer sweep — read-ratio x skew x K (qplock shared vs \
         exclusive-only vs RPC server; counted mode)",
        &[
            "config",
            "read%",
            "skew",
            "K",
            "reads",
            "writes",
            "rounds",
            "rd-wait p50",
            "rd-wait p99",
            "wr-wait p50",
            "wr-wait p99",
            "max-rd-overlap",
            "fabric/op",
            "violations",
        ],
    );
    let wait = |h: &crate::stats::Histogram, q: f64| {
        if h.count() == 0 {
            "-".to_string()
        } else {
            h.quantile(q).to_string()
        }
    };
    for &(rr, skew) in combos {
        let sh = rw_probe(true, procs, k, iters, rr, skew);
        let ex = rw_probe(false, procs, k, iters, rr, skew);
        let rp = rpc_probe(procs, k, iters, rr, skew);
        // Same seeds everywhere, so the three runs execute the same op
        // stream — the columns differ only in how the lock admits it.
        assert_eq!(sh.reads, ex.reads, "shared/exclusive draw streams diverged");
        assert_eq!(sh.reads, rp.reads, "qplock/rpc draw streams diverged");
        // The budget word arbitrates shared batches like any other
        // cohort: the writer tail stays bounded even at peak skew.
        assert!(
            sh.write_wait.count() == 0 || sh.write_wait.p99() <= 16 * procs as u64,
            "writer p99 unbounded under shared batches: {} rounds",
            sh.write_wait.p99()
        );
        for (cfg, s) in [("qplock rw", &sh), ("qplock excl", &ex), ("rpc excl", &rp)] {
            assert_eq!(
                s.violations, 0,
                "{cfg}: per-mode overlap oracle violated at rr={rr} skew={skew} K={k}"
            );
            t.row(&[
                cfg.into(),
                format!("{:.0}", rr * 100.0),
                format!("{skew}"),
                k.to_string(),
                s.reads.to_string(),
                s.writes.to_string(),
                s.rounds.to_string(),
                wait(&s.read_wait, 0.50),
                wait(&s.read_wait, 0.99),
                wait(&s.write_wait, 0.50),
                wait(&s.write_wait, 0.99),
                s.max_read_overlap.to_string(),
                format!("{:.2}", s.fabric_ops as f64 / (s.reads + s.writes).max(1) as f64),
                s.violations.to_string(),
            ]);
        }
    }
    ExpOutput {
        id: "e14",
        tables: vec![ht, t],
        notes: vec![
            format!(
                "{procs} actors (sessions over 3 nodes) x {iters} ops each; lock drawn \
                 Zipfian over K locks, mode Bernoulli(read%); held sections span one \
                 extra scheduler round so admissions can overlap observably"
            ),
            "rounds = scheduler rounds until every actor finished — the concurrency \
             proxy: shared-mode readers overlap, so high read% completes in fewer \
             rounds than the same draw stream run exclusive-only"
                .into(),
            "rpc rows are closed-loop blocking round trips (nothing held across \
             turns): reads can never overlap (max-rd-overlap 1) and every op pays \
             the request/reply fabric cost, server CPU included in fabric/op"
                .into(),
            "invariants, asserted not just reported: zero per-mode oracle violations \
             in every cell (readers never overlap a writer, writers overlap \
             nothing); identical op streams across configs; writer wait p99 \
             bounded at peak skew; headline: all N readers hold concurrently, the \
             writer drains the generation, and the generation reopens"
                .into(),
        ],
    }
}

// ------------------------------------------------------------------ E15

/// Result of one [`handoff_probe`] configuration.
struct HandoffStats {
    /// Signalled remote handoffs driven (each one metered release).
    handoffs: u64,
    /// WQEs (NIC ops, both NICs) issued inside the release+signal window.
    release_wqes: u64,
    /// Doorbells rung inside the release+signal window.
    release_doorbells: u64,
    /// Modeled fabric ns attributed to the passer across those windows.
    release_net_ns: u64,
}

impl HandoffStats {
    fn per(&self, x: u64) -> String {
        format!("{:.2}", x as f64 / self.handoffs.max(1) as f64)
    }
}

fn nic_totals(d: &RdmaDomain) -> (u64, u64) {
    use std::sync::atomic::Ordering::SeqCst;
    let mut ops = 0;
    let mut doorbells = 0;
    for n in 0..d.num_nodes() {
        ops += d.node(n).nic.metrics.ops.load(SeqCst);
        doorbells += d.node(n).nic.metrics.doorbells.load(SeqCst);
    }
    (ops, doorbells)
}

/// Drive `iters` signalled remote handoffs on each of `k` independent
/// qplock instances homed on node 0, holder and waiter both on node 1
/// — the §3.1 hot path where the release's budget rWrite, registration
/// reads, and ring publish all target the successor's node and (with
/// batching on) chain into one doorbell. Single OS thread, counted
/// mode: every run is bit-deterministic. Only the release+signal
/// window is metered; the waiter parks in `WaitBudget` and arms its
/// wakeup *before* the release, so every metered unlock is a signalled
/// handoff, never a tail reset.
fn handoff_probe(batch: bool, congested: bool, k: u32, iters: u64) -> HandoffStats {
    let mut lat = LatencyModel::calibrated();
    if congested {
        // E7's loopback-congestion shape: a tight NIC pipeline. The
        // congestion-aware pacing policy caps each chain at
        // `nic_capacity`, so batched chains never model queue overflow
        // — the cost surfaces as extra doorbells, not congestion ns.
        lat.nic_capacity = 2;
        lat.congestion_ns_per_op = 2_000;
    } else {
        lat.congestion_ns_per_op = 0;
    }
    let cfg = DomainConfig {
        latency: lat,
        time_mode: TimeMode::Counted,
        atomicity: AtomicityMode::NicSerialized,
        hazard_ns: 0,
        pad_lines: true,
        batching: batch,
    };
    let d = RdmaDomain::new(2, 1 << 18, cfg);
    let mut s = HandoffStats {
        handoffs: 0,
        release_wqes: 0,
        release_doorbells: 0,
        release_net_ns: 0,
    };
    for _ in 0..k {
        // Budget far above `iters` so every handoff stays on the
        // budget-write path (no mid-row Peterson re-engage).
        let lock = make_lock("qplock", &d, 0, 4, 1 << 20);
        let hold_ep = d.endpoint(1);
        let hold_m = Arc::clone(&hold_ep.metrics);
        let mut holder = lock.handle(hold_ep, 0);
        let mut waiter = lock.handle(d.endpoint(1), 1);
        let mut ring = WakeupRing::new(d.endpoint(1), 4);
        for it in 0..iters {
            holder.lock();
            // Enqueue the waiter, park it on its budget word, arm.
            {
                let w = waiter.as_async().expect("qplock is poll-capable");
                let mut polls = 0;
                while w.phase() != AcqPhase::WaitBudget {
                    assert!(w.poll_lock().is_pending(), "waiter resolved under a held lock");
                    polls += 1;
                    assert!(polls < 64, "waiter never parked on WaitBudget");
                }
                let token = it & 0xFFFF_FFFF;
                let armed = w.arm_wakeup(WakeupReg {
                    ring: ring.header(),
                    token,
                    ring_slots: ring.lane_slots(),
                });
                assert_eq!(armed, ArmOutcome::Armed, "park strictly precedes the release");
            }
            // Meter exactly the release+signal window.
            let (ops0, dbs0) = nic_totals(&d);
            let ns0 = hold_m.snapshot().net_ns;
            holder.unlock();
            let (ops1, dbs1) = nic_totals(&d);
            s.release_wqes += ops1 - ops0;
            s.release_doorbells += dbs1 - dbs0;
            s.release_net_ns += hold_m.snapshot().net_ns - ns0;
            s.handoffs += 1;
            // The successor completes, consumes its token, and releases
            // uncontended (tail reset) outside the metered window.
            let w = waiter.as_async().expect("qplock is poll-capable");
            let mut polls = 0;
            while !w.poll_lock().is_held() {
                polls += 1;
                assert!(polls < 64, "signalled waiter never acquired");
            }
            assert_eq!(ring.pop(), Some(it & 0xFFFF_FFFF), "handoff token lost");
            assert_eq!(ring.pop(), None);
            waiter.unlock();
        }
    }
    s
}

/// Doorbell-batching ablation (the tentpole's E15): batch on/off ×
/// NIC congestion × lock count K, all on the signalled remote-handoff
/// path. Headline: with batching on and an uncongested NIC, the whole
/// release+signal — budget rWrite, two registration reads, ring
/// publish — rings **one** doorbell; unbatched issue rings one per
/// WQE. Under the congested (capacity-2) NIC the pacing policy splits
/// the chain rather than modeling queue overflow, so doorbells rise
/// but congestion ns stays zero.
fn e15_doorbell_ablation(scale: Scale) -> ExpOutput {
    let (ks, iters): (&[u32], u64) = match scale {
        Scale::Quick => (&[1, 16], 8),
        Scale::Full => (&[1, 16, 256], 64),
    };
    let mut t = Table::new(
        "E15: doorbell batching ablation — signalled remote handoffs (qplock, counted mode)",
        &[
            "batch",
            "nic",
            "K",
            "handoffs",
            "WQEs/handoff",
            "doorbells/handoff",
            "fabric-ns/handoff",
        ],
    );
    for congested in [false, true] {
        for batch in [false, true] {
            for &k in ks {
                let s = handoff_probe(batch, congested, k, iters);
                // Invariants, asserted not just reported: batching
                // never changes the WQE stream, only how it is issued;
                // uncongested batching collapses the release to one
                // doorbell; unbatched issue rings one per WQE.
                assert_eq!(
                    s.release_wqes % s.handoffs,
                    0,
                    "release verb count must not drift across handoffs"
                );
                if batch && !congested {
                    assert_eq!(s.release_doorbells, s.handoffs, "one doorbell per handoff");
                }
                if !batch {
                    assert_eq!(s.release_doorbells, s.release_wqes, "unbatched: 1 doorbell/WQE");
                }
                t.row(&[
                    (if batch { "on" } else { "off" }).into(),
                    (if congested { "congested" } else { "uncongested" }).into(),
                    k.to_string(),
                    s.handoffs.to_string(),
                    s.per(s.release_wqes),
                    s.per(s.release_doorbells),
                    s.per(s.release_net_ns),
                ]);
            }
        }
    }
    ExpOutput {
        id: "e15",
        tables: vec![t],
        notes: vec![
            "scenario: K independent qplocks homed on node 0; holder and armed waiter \
             on node 1; every metered release is a signalled remote handoff (budget \
             rWrite + registration reads + ring publish, all to the successor's node)"
                .into(),
            "batch=on, uncongested: the whole release+signal chains into exactly one \
             doorbell (the §Perf fabric-transactions-per-handoff headline); unbatched \
             issue rings one doorbell per WQE"
                .into(),
            "congested = E7's tight NIC (capacity 2, 2000 ns/op overflow): the pacing \
             policy caps each chain at nic_capacity, so the congested column shows \
             more doorbells per handoff — never modeled queue overflow (congestion \
             ns stays 0 in counted mode; see Nic::admit_batch)"
                .into(),
            "counted mode + one OS thread: every cell is bit-deterministic, which is \
             what lets the batched-vs-unbatched WQE streams be asserted identical"
                .into(),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_ids() {
        assert_eq!(EXPERIMENTS.len(), 15);
        for (id, _) in EXPERIMENTS {
            assert!(id.starts_with('e'));
        }
    }

    #[test]
    fn e13_quick_is_the_crash_acceptance_run() {
        // ISSUE 4 acceptance: ≥64 procs, ≥100 locks, crashes injected
        // at all four named protocol points, zero mutual-exclusion
        // violations (asserted inside e13 too), zero wedged survivors,
        // and revoked epochs' late writes provably fenced.
        let out = run_experiment("e13", Scale::Quick);
        let t = &out.tables[0];
        assert_eq!(t.rows(), 3);
        let mut saw_fenced_late_write = false;
        for r in 0..t.rows() {
            assert_eq!(t.cell(r, 4), "4", "row {r}: all four protocol points injected");
            assert_eq!(t.cell(r, 11), "0", "row {r}: violations");
            assert_eq!(t.cell(r, 12), "no", "row {r}: wedged survivors");
            let revoked: u64 = t.cell(r, 5).parse().unwrap();
            assert!(revoked >= 4, "row {r}: forced crashes were never revoked");
            saw_fenced_late_write |= t.cell(r, 7) != "0";
        }
        assert!(
            saw_fenced_late_write,
            "no zombie late write was ever fenced — the writeback race went unexercised"
        );
        // ISSUE 10 satellite: the worker-thread-kill table rides along.
        // One worker died mid-run, sessions were stolen and completed
        // (readers and writers both), and no lock was stranded.
        let wt = &out.tables[1];
        assert_eq!(wt.rows(), 1);
        assert_eq!(wt.cell(0, 3), "96", "completed cycles");
        assert_ne!(wt.cell(0, 4), "0", "reader cycles crossed the kill");
        assert_ne!(wt.cell(0, 5), "0", "writer cycles crossed the kill");
        assert_eq!(wt.cell(0, 8), "0", "lost locks");
    }

    #[test]
    fn e14_quick_is_the_shared_mode_acceptance_run() {
        // ISSUE 10 acceptance: readers share the generation (headline:
        // all N concurrent), the per-mode oracle holds in every cell,
        // the same draw stream completes in strictly fewer rounds with
        // shared admission at a high read ratio, and the RPC baseline
        // never overlaps readers.
        let out = run_experiment("e14", Scale::Quick);
        let ht = &out.tables[0];
        assert_eq!(ht.rows(), 1);
        assert_eq!(ht.cell(0, 0), ht.cell(0, 1), "all headline readers held concurrently");
        assert_eq!(ht.cell(0, 3), "yes", "generation must reopen after the writer");

        let t = &out.tables[1];
        assert_eq!(t.rows(), 6); // 2 (read%, skew) combos x 3 configs
        for r in 0..t.rows() {
            assert_eq!(t.cell(r, 13), "0", "row {r}: oracle violations");
            if t.cell(r, 0).starts_with("rpc") {
                assert_eq!(t.cell(r, 11), "1", "row {r}: rpc reads can never overlap");
            }
        }
        // Rows 3..6 are the 95%-read combo: qplock rw / qplock excl /
        // rpc excl. Shared admission must beat exclusive-only on the
        // identical draw stream, via genuine reader overlap.
        let sh_rounds: u64 = t.cell(3, 6).parse().unwrap();
        let ex_rounds: u64 = t.cell(4, 6).parse().unwrap();
        assert!(
            sh_rounds < ex_rounds,
            "shared admission did not shorten the 95%-read run ({sh_rounds} vs {ex_rounds})"
        );
        let overlap: u32 = t.cell(3, 11).parse().unwrap();
        assert!(overlap >= 2, "no reader overlap ever observed in the shared run");
    }

    #[test]
    fn e12_quick_ready_mode_scales_with_ready_count_not_pending() {
        let out = run_experiment("e12", Scale::Quick);
        let t = &out.tables[0];
        assert_eq!(t.rows(), 4);
        // Rows: (1k scan), (1k ready), (10k scan), (10k ready).
        for (scan_row, ready_row, k) in [(0, 1, 1_000f64), (2, 3, 10_000f64)] {
            let scan: f64 = t.cell(scan_row, 5).parse().unwrap();
            let ready: f64 = t.cell(ready_row, 5).parse().unwrap();
            assert!(
                scan >= k * 0.9,
                "scan polls/release should be O(pending): {scan} at K={k}"
            );
            assert!(
                ready <= 4.0,
                "ready polls/release should be O(1): {ready} at K={k}"
            );
        }
        // E12b: the executor fleet with every fallback sweep disabled
        // — both waiter classes must complete on ~1 poll per release.
        let t2 = &out.tables[1];
        assert_eq!(t2.rows(), 2);
        for (r, class) in [(0, "budget-parked"), (1, "peterson-leader")] {
            assert_eq!(t2.cell(r, 3), class);
            let ppr: f64 = t2.cell(r, 6).parse().unwrap();
            assert!(
                ppr <= 4.0,
                "{class}: sweep-disabled polls/release should be O(1): {ppr}"
            );
        }
    }

    #[test]
    fn e11_quick_compares_modes_side_by_side() {
        // The acceptance run: 64 simulated processes over >= 100 named
        // locks on 4 OS threads, zero oracle violations, local-class
        // handles NIC-clean, and both mode columns populated.
        let out = run_experiment("e11", Scale::Quick);
        let t = &out.tables[0];
        assert_eq!(t.rows(), 4);
        for r in 0..t.rows() {
            let tpp: f64 = t.cell(r, 2).parse().unwrap();
            let mux: f64 = t.cell(r, 3).parse().unwrap();
            assert!(tpp > 0.0, "row {r}: thread-per-process throughput");
            assert!(mux > 0.0, "row {r}: multiplexed throughput");
            assert_eq!(t.cell(r, 5), "0", "row {r}: local-class rdma");
            assert_eq!(t.cell(r, 7), "0", "row {r}: violations");
        }
        assert_eq!(t.lookup("10000", 1), Some("0.00"));
    }

    #[test]
    fn e10_quick_runs_the_table_sweep_clean() {
        let out = run_experiment("e10", Scale::Quick);
        let t = &out.tables[0];
        assert_eq!(t.rows(), 5);
        for r in 0..t.rows() {
            // Zero local-class RDMA verbs and zero violations in every
            // configuration, including the 10k-lock Zipfian row.
            assert_eq!(t.cell(r, 4), "0", "row {r}: local-class rdma");
            assert_eq!(t.cell(r, 9), "0", "row {r}: violations");
        }
        // The 10k row actually spans a large keyspace.
        assert_eq!(t.lookup("10000", 2), Some("hash"));
        let touched: u64 = t.lookup("10000", 7).unwrap().parse().unwrap();
        assert!(touched > 100, "10k sweep touched only {touched} locks");
        // Skewed rows concentrate load; uniform K=100 must not.
        let hot_skew: f64 = t.cell(2, 6).parse().unwrap();
        let hot_unif: f64 = t.cell(1, 6).parse().unwrap();
        assert!(hot_skew > hot_unif, "zipf skew invisible: {hot_skew} vs {hot_unif}");
    }

    #[test]
    fn e2_quick_runs_and_qplock_locals_are_zero() {
        let out = run_experiment("e2", Scale::Quick);
        let t = &out.tables[0];
        assert_eq!(t.lookup("qplock", 1), Some("0.00"), "local rdma ops");
        assert_eq!(t.lookup("qplock", 2), Some("0.00"), "local loopback");
        // qplock lone-remote: exactly 2 rCAS per lock+unlock cycle.
        assert_eq!(t.lookup("qplock", 3), Some("2.00"));
        // E2b (§Perf: fabric transactions per signalled remote
        // handoff): batching collapses the release+signal to one
        // doorbell without changing the WQE stream.
        let t2 = &out.tables[1];
        assert_eq!(t2.lookup("batched", 3), Some("1.00"), "doorbells/handoff");
        assert_eq!(
            t2.lookup("batched", 2),
            t2.lookup("unbatched", 2),
            "batching must not change the WQE stream"
        );
        let unbatched: f64 = t2.lookup("unbatched", 3).unwrap().parse().unwrap();
        assert!(
            unbatched >= 2.0,
            "unbatched handoff should ring one doorbell per WQE: {unbatched}"
        );
    }

    #[test]
    fn e15_quick_batching_amortizes_doorbells_not_wqes() {
        let out = run_experiment("e15", Scale::Quick);
        let t = &out.tables[0];
        // 2 congestion settings x 2 issue modes x 2 K values.
        assert_eq!(t.rows(), 8);
        for r in 0..t.rows() {
            let wqes: f64 = t.cell(r, 4).parse().unwrap();
            let dbs: f64 = t.cell(r, 5).parse().unwrap();
            assert!(wqes >= 2.0, "row {r}: a signalled handoff is multi-WQE");
            if t.cell(r, 0) == "off" {
                assert_eq!(t.cell(r, 5), t.cell(r, 4), "row {r}: unbatched rings per WQE");
            } else {
                assert!(dbs < wqes, "row {r}: batching must amortize doorbells");
            }
        }
        // The WQE stream is invariant across every cell: same protocol,
        // same verbs, whatever the issue mode, congestion, or K.
        let wqes0 = t.cell(0, 4);
        for r in 1..t.rows() {
            assert_eq!(t.cell(r, 4), wqes0, "row {r}: WQE stream moved");
        }
        // Congested (capacity-2) batching pays extra doorbells — the
        // pacing cap — but stays strictly better than unbatched issue.
        let db = |batch: &str, nic: &str| -> f64 {
            (0..t.rows())
                .find(|&r| t.cell(r, 0) == batch && t.cell(r, 1) == nic && t.cell(r, 2) == "1")
                .map(|r| t.cell(r, 5).parse().unwrap())
                .expect("row present")
        };
        assert_eq!(db("on", "uncongested"), 1.0);
        assert!(db("on", "congested") > db("on", "uncongested"));
        assert!(db("on", "congested") < db("off", "congested"));
    }

    /// Satellite regression (counted-mode congestion pricing): with the
    /// E7 NIC shape (capacity 2, 2000 ns/op overflow) and 8 concurrent
    /// processes hammering node 0, counted-mode attribution must be a
    /// pure function of each process's own op stream — identical across
    /// runs and across schedules, with zero congestion charged (a lone
    /// verb's modeled depth never exceeds capacity). Before the fix,
    /// `Nic::admit` priced counted congestion from the racing inflight
    /// gauge, so this exact setup produced nonzero, run-to-run-varying
    /// totals.
    #[test]
    fn e7_shaped_counted_pricing_is_schedule_independent() {
        use crate::rdma::Addr;
        use std::sync::atomic::Ordering::SeqCst;

        fn run_once() -> (Vec<u64>, u64) {
            let mut lat = LatencyModel::calibrated();
            lat.nic_capacity = 2;
            lat.congestion_ns_per_op = 2_000;
            let cfg = DomainConfig {
                latency: lat,
                time_mode: TimeMode::Counted,
                atomicity: AtomicityMode::NicSerialized,
                hazard_ns: 0,
                pad_lines: true,
                batching: false,
            };
            let d = RdmaDomain::new(2, 1 << 14, cfg);
            let base = d.endpoint(0).alloc(8);
            let mut per_proc = Vec::new();
            std::thread::scope(|s| {
                let mut joins = Vec::new();
                for p in 0..8u32 {
                    // E7's spread: 6 loopback-heavy procs on the home
                    // node, 2 remote.
                    let ep = d.endpoint(if p < 6 { 0 } else { 1 });
                    let target = Addr::new(0, base.word() + p);
                    joins.push(s.spawn(move || {
                        for i in 0..100u64 {
                            ep.r_write(target, i);
                            ep.r_read(target);
                        }
                        ep.metrics.snapshot().net_ns
                    }));
                }
                for j in joins {
                    per_proc.push(j.join().unwrap());
                }
            });
            let cong = d.node(0).nic.metrics.congestion_penalty_ns.load(SeqCst);
            (per_proc, cong)
        }

        let (a, cong_a) = run_once();
        let (b, cong_b) = run_once();
        assert_eq!(a, b, "counted net_ns must not depend on thread schedule");
        assert_eq!(cong_a, 0, "lone-verb modeled depth never exceeds capacity");
        assert_eq!(cong_b, 0);
        // And the totals are the closed-form sum of base costs.
        let lat = LatencyModel::calibrated();
        assert_eq!(
            a[0],
            100 * (lat.loopback_write_ns + lat.loopback_read_ns),
            "loopback proc: exact base-cost attribution"
        );
        assert_eq!(
            a[7],
            100 * (lat.remote_write_ns + lat.remote_read_ns),
            "remote proc: exact base-cost attribution"
        );
    }

    #[test]
    fn e8_quick_matches_paper_verdicts() {
        let out = run_experiment("e8", Scale::Quick);
        let t = &out.tables[0];
        // qplock rows all PASS.
        for r in 0..t.rows() {
            if t.cell(r, 0) == "qplock" {
                for c in 3..=6 {
                    assert_eq!(t.cell(r, c), "PASS", "row {r} col {c}");
                }
            }
            if t.cell(r, 0) == "naive-mixed" {
                assert_eq!(t.cell(r, 3), "FAIL");
            }
            if t.cell(r, 0) == "spin-rcas" {
                assert_eq!(t.cell(r, 3), "PASS");
                assert_eq!(t.cell(r, 5), "FAIL");
            }
        }
    }

    #[test]
    fn e1_quick_reproduces_table1() {
        let out = run_experiment("e1", Scale::Quick);
        let t = &out.tables[0];
        // Write and RMW rows: commodity rCAS cell must report lost
        // updates, global cell must be clean.
        for key in ["Write", "RMW"] {
            let comm = t.lookup(key, 3).unwrap();
            let glob = t.lookup(key, 4).unwrap();
            assert!(comm.starts_with("No"), "{key} commodity: {comm}");
            assert_eq!(glob, "Yes", "{key} global");
        }
    }
}
