//! Minimal aligned-table / CSV printer for experiment output (criterion
//! is not in the vendored registry; the harness prints the same
//! rows/series the paper's evaluation would).

/// A simple column-aligned table.
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    pub fn cell(&self, r: usize, c: usize) -> &str {
        &self.rows[r][c]
    }

    /// Find a cell by (value of first column, column index).
    pub fn lookup(&self, key: &str, col: usize) -> Option<&str> {
        self.rows
            .iter()
            .find(|r| r[0] == key)
            .map(|r| r[col].as_str())
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        writeln!(f, "== {} ==", self.title)?;
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:>w$}", h, w = widths[i]))
            .collect();
        writeln!(f, "{}", hdr.join("  "))?;
        writeln!(
            f,
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        )?;
        for r in &self.rows {
            let cells: Vec<String> = r
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            writeln!(f, "{}", cells.join("  "))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_formats() {
        let mut t = Table::new("demo", &["algo", "thr"]);
        t.row(&["qplock".into(), "123".into()]);
        t.row(&["spin".into(), "45".into()]);
        let s = format!("{t}");
        assert!(s.contains("== demo =="));
        assert!(s.contains("qplock"));
        assert_eq!(t.rows(), 2);
        assert_eq!(t.lookup("spin", 1), Some("45"));
        assert_eq!(t.lookup("nope", 1), None);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into()]);
    }
}
