//! Benchmark/experiment harness (system S11).
//!
//! criterion is not in the vendored registry, so `cargo bench` runs
//! `rust/benches/bench_main.rs` (`harness = false`), which calls
//! [`experiments::run_experiment`] for every id at `Quick` scale; the
//! CLI (`qplock bench --exp eN --full`) runs individual experiments at
//! the EXPERIMENTS.md scale. Each experiment prints aligned tables (and
//! can emit CSV) mirroring the rows/series a paper evaluation would
//! plot.

pub mod experiments;
pub mod table;

pub use experiments::{run_experiment, ExpOutput, Scale, EXPERIMENTS};
pub use table::Table;
