//! `cargo bench` entry point (criterion is not in the vendored
//! registry; this is a `harness = false` bench).
//!
//! Runs every experiment in DESIGN.md's index (E1–E9) at Quick scale
//! plus the hot-path microbenchmarks used by the §Perf iteration log.
//! Full-scale runs: `qplock bench --exp <id> --full`.

use std::time::Instant;

use qplock::bench::{run_experiment, Scale, EXPERIMENTS};
use qplock::coordinator::{run_workload, Cluster, Workload};
use qplock::locks::make_lock;
use qplock::rdma::DomainConfig;
use qplock::stats::Welford;

/// Microbenchmark: median ns per uncontended lock+unlock cycle.
fn micro_uncontended(algo: &str, counted: bool, local: bool) -> f64 {
    let cfg = if counted {
        DomainConfig::counted()
    } else {
        DomainConfig::timed()
    };
    let cluster = Cluster::new(2, 1 << 16, cfg);
    let lock = make_lock(algo, &cluster.domain, 0, 2, 8);
    let node = if local { 0 } else { 1 };
    let mut h = lock.handle(cluster.domain.endpoint(node), 0);
    // Warmup.
    for _ in 0..1_000 {
        h.lock();
        h.unlock();
    }
    let mut w = Welford::default();
    for _ in 0..5 {
        let iters = 20_000;
        let t0 = Instant::now();
        for _ in 0..iters {
            h.lock();
            h.unlock();
        }
        w.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    w.mean()
}

fn main() {
    println!("################ qplock bench suite ################\n");

    println!("== hot path: uncontended lock+unlock cycle (ns, mean of 5x20k) ==");
    println!(
        "{:<12} {:>14} {:>14} {:>16}",
        "algo", "local/counted", "local/timed", "remote/counted"
    );
    for algo in ["qplock", "rdma-mcs", "spin-rcas", "cohort-tas"] {
        let lc = micro_uncontended(algo, true, true);
        let lt = micro_uncontended(algo, false, true);
        let rc = micro_uncontended(algo, true, false);
        println!("{algo:<12} {lc:>14.0} {lt:>14.0} {rc:>16.0}");
    }
    println!();

    println!("== contended handoff: 4 procs, counted mode, cycles/s ==");
    for algo in ["qplock", "rdma-mcs", "spin-rcas"] {
        let cluster = Cluster::new(2, 1 << 18, DomainConfig::counted());
        let lock = make_lock(algo, &cluster.domain, 0, 4, 8);
        let procs = cluster.spread_procs(4, 2, 0);
        let r = run_workload(&cluster.domain, &lock, &procs, &Workload::cycles(5_000));
        assert_eq!(r.violations, 0);
        println!(
            "{algo:<12} {:>12.0} acq/s   jain {:.3}",
            r.throughput(),
            r.jain()
        );
    }
    println!();

    for (id, desc) in EXPERIMENTS {
        let t0 = Instant::now();
        let out = run_experiment(id, Scale::Quick);
        println!("{out}");
        println!(
            "[{id} ({desc}) took {:.1}s]\n",
            t0.elapsed().as_secs_f64()
        );
    }
    println!("bench suite complete.");
}
