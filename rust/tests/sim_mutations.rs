#![cfg(debug_assertions)]
//! Mutation teeth: prove the schedule explorer can actually find bugs
//! by disabling one known defense at a time
//! (`qplock::locks::test_knobs`) and asserting the seeded exploration
//! rediscovers the protocol violation it guards — within a bounded
//! schedule budget — then shrinks it to a minimal counterexample whose
//! replay reproduces the violation deterministically (ISSUE 5
//! acceptance: ≤ 2000 schedules per knob).
//!
//! The knobs are process-global statics, so the three tests serialize
//! on one mutex and reset the knobs on entry and exit. This file is
//! its own test binary: no other test shares its process.

use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::sync::{Mutex, MutexGuard};

use qplock::locks::test_knobs;
use qplock::sim::{self, explore, SchedMode, SimConfig};

static KNOBS: Mutex<()> = Mutex::new(());

fn serialized() -> MutexGuard<'static, ()> {
    let g = KNOBS.lock().unwrap_or_else(|p| p.into_inner());
    test_knobs::reset();
    g
}

/// Run the full find → shrink → replay pipeline for one armed knob:
/// a defended sanity sweep first (no violation with the knob off),
/// then exploration with the knob on must find `kind` within
/// `budget` schedules, shrink it, and replay it deterministically
/// (twice, plus once through the artifact file).
fn assert_tooth(
    label: &str,
    knob: &AtomicBool,
    cfg: &SimConfig,
    budget: u32,
    defended_budget: u32,
    kind: &str,
) -> sim::ExploreReport {
    let defended = explore(cfg, defended_budget, 1, None);
    assert!(
        defended.violation.is_none(),
        "{label}: defended run violated: {:?}",
        defended.violation
    );

    knob.store(true, SeqCst);
    let dir = std::path::Path::new("target/sim-artifacts");
    let report = explore(cfg, budget, 1, Some(dir));
    let (seed, v) = report
        .violation
        .as_ref()
        .unwrap_or_else(|| panic!("{label}: not rediscovered within {budget} schedules"));
    assert_eq!(v.kind(), kind, "{label}: wrong violation at seed {seed}");
    let tf = report.shrunk.as_ref().expect("violations are shrunk");
    assert!(
        tf.steps.len() < cfg.max_steps as usize,
        "{label}: shrink made no progress ({} steps)",
        tf.steps.len()
    );
    // Deterministic replay: twice in-process, once through the
    // artifact file round trip.
    let r1 = sim::replay(&tf.config, &tf.steps);
    let r2 = sim::replay(&tf.config, &tf.steps);
    assert_eq!(r1.violation, r2.violation, "{label}: replay nondeterministic");
    assert_eq!(
        r1.violation.as_ref().map(|v| v.kind()),
        Some(kind),
        "{label}: shrunk trace lost the violation"
    );
    let artifact = report.artifact.as_ref().expect("artifact written");
    let (r3, claimed) = sim::replay::replay_file(artifact).expect("artifact parses");
    assert_eq!(claimed.as_deref(), Some(kind), "{label}: artifact header");
    assert_eq!(
        r3.violation.as_ref().map(|v| v.kind()),
        Some(kind),
        "{label}: artifact replay lost the violation"
    );
    test_knobs::reset();
    // And the minimal trace is clean again once the defense is back:
    // the violation lived in the protocol, not in the harness.
    let healed = sim::replay(&tf.config, &tf.steps);
    assert!(
        healed.violation.is_none(),
        "{label}: defended replay of the counterexample still fails: {:?}",
        healed.violation
    );
    report
}

#[test]
fn skip_arm_recheck_loses_a_wakeup_and_is_rediscovered() {
    // PR 3 defense: `arm_wakeup` re-checks the budget word after
    // publishing the registration, closing the store-load race with a
    // passer whose handoff landed first. With the re-check skipped, an
    // arm scheduled after the handoff parks the waiter on a token
    // nobody will publish — a lost wakeup the drain exposes as a
    // wedge. Manual-arm mode makes the arm its own schedulable step,
    // so the explorer can place it after the release.
    let _g = serialized();
    let cfg = SimConfig {
        procs: 3,
        locks: 2,
        nodes: 1,
        budget: 4,
        lease_ticks: 64,
        ring_capacity: 8,
        max_steps: 300,
        drain_rounds: 3_000,
        crash_prob: 0.0,
        zombie_prob: 0.0,
        max_crashes: 0,
        manual_arm: true,
        executor_steps: false,
        race_detect: false,
        shared: false,
        mode: SchedMode::Uniform,
    };
    assert_tooth(
        "skip-arm-recheck",
        &test_knobs::SKIP_ARM_RECHECK,
        &cfg,
        2_000,
        150,
        "wedged",
    );
}

#[test]
fn skip_waker_recheck_loses_an_engaged_wakeup_and_is_rediscovered() {
    // PR 7 defense: `arm_peterson` re-checks the Peterson win
    // condition after publishing the waker-block registration — the
    // engaged-class twin of `arm_wakeup`'s budget re-check. With it
    // skipped, an arm scheduled after the other cohort's last tail
    // reset (or victim write) parks the leader on a token nobody will
    // ever publish — a lost wakeup the token-only drain exposes as a
    // wedge. Two nodes put actors in both classes (a one-node world
    // never blocks in the Peterson wait); one lock concentrates the
    // cross-class contention; manual-arm mode makes the late arm its
    // own schedulable step.
    let _g = serialized();
    let cfg = SimConfig {
        procs: 3,
        locks: 1,
        nodes: 2,
        budget: 2,
        lease_ticks: 64,
        ring_capacity: 8,
        max_steps: 400,
        drain_rounds: 3_000,
        crash_prob: 0.0,
        zombie_prob: 0.0,
        max_crashes: 0,
        manual_arm: true,
        executor_steps: false,
        race_detect: false,
        shared: false,
        mode: SchedMode::Uniform,
    };
    assert_tooth(
        "skip-waker-recheck",
        &test_knobs::SKIP_WAKER_RECHECK,
        &cfg,
        2_000,
        150,
        "wedged",
    );
}

/// ISSUE 8 acceptance: the vector-clock race detector reports the
/// `SKIP_ARM_RECHECK` mutation as a *named missing edge* — and does it
/// in strictly fewer schedules than the wedge oracle's 2000-schedule
/// bound, because the detector condemns the first unrechecked arm
/// rather than waiting for the schedule where the race actually loses
/// the wakeup.
#[test]
fn race_detector_names_the_arm_budget_edge_for_skip_arm_recheck() {
    let _g = serialized();
    let cfg = SimConfig {
        procs: 3,
        locks: 2,
        nodes: 1,
        budget: 4,
        lease_ticks: 64,
        ring_capacity: 8,
        max_steps: 300,
        drain_rounds: 3_000,
        crash_prob: 0.0,
        zombie_prob: 0.0,
        max_crashes: 0,
        manual_arm: true,
        executor_steps: false,
        race_detect: true,
        shared: false,
        mode: SchedMode::Uniform,
    };
    let report = assert_tooth(
        "skip-arm-recheck-race",
        &test_knobs::SKIP_ARM_RECHECK,
        &cfg,
        50, // ≪ the wedge oracle's 2000-schedule bound
        150,
        "order-race",
    );
    match report.violation.expect("asserted by assert_tooth").1 {
        sim::Violation::OrderRace { edge, word, .. } => {
            assert_eq!(edge, "arm-budget-window", "wrong edge named");
            assert_eq!(word, "wake-ring", "wrong gate word named");
        }
        other => panic!("expected OrderRace, got {other:?}"),
    }
}

/// The PR 7 twin: `SKIP_WAKER_RECHECK` is condemned by the detector as
/// the `peterson-waker-block` edge's dropped re-check, again in far
/// fewer schedules than the wedge-oracle rediscovery.
#[test]
fn race_detector_names_the_peterson_edge_for_skip_waker_recheck() {
    let _g = serialized();
    let cfg = SimConfig {
        procs: 3,
        locks: 1,
        nodes: 2,
        budget: 2,
        lease_ticks: 64,
        ring_capacity: 8,
        max_steps: 400,
        drain_rounds: 3_000,
        crash_prob: 0.0,
        zombie_prob: 0.0,
        max_crashes: 0,
        manual_arm: true,
        executor_steps: false,
        race_detect: true,
        shared: false,
        mode: SchedMode::Uniform,
    };
    let report = assert_tooth(
        "skip-waker-recheck-race",
        &test_knobs::SKIP_WAKER_RECHECK,
        &cfg,
        200, // ≪ the wedge oracle's 2000-schedule bound
        150,
        "order-race",
    );
    match report.violation.expect("asserted by assert_tooth").1 {
        sim::Violation::OrderRace { edge, word, .. } => {
            assert_eq!(edge, "peterson-waker-block", "wrong edge named");
            assert_eq!(word, "waker-ring", "wrong gate word named");
        }
        other => panic!("expected OrderRace, got {other:?}"),
    }
}

#[test]
fn ignore_dirty_tokens_overwrites_a_live_token_and_is_rediscovered() {
    // PR 3 defense: the session arming bound counts released-but-
    // maybe-unconsumed (dirty) tokens, so ring lanes can never lap the
    // consumer. Counting only live registrations lets the churn
    // profile — re-arm, resolve host-side by direct poll, repeat —
    // push enough publications through a capacity-2 ring to overwrite
    // an earlier live token: that waiter is never signalled and the
    // drain wedges.
    let _g = serialized();
    let cfg = SimConfig {
        procs: 3,
        locks: 2,
        nodes: 1,
        budget: 6,
        lease_ticks: 200,
        ring_capacity: 2,
        max_steps: 1_500,
        drain_rounds: 4_000,
        crash_prob: 0.0,
        zombie_prob: 0.0,
        max_crashes: 0,
        manual_arm: true,
        executor_steps: false,
        race_detect: false,
        shared: false,
        mode: SchedMode::Churn,
    };
    assert_tooth(
        "ignore-dirty-tokens",
        &test_knobs::IGNORE_DIRTY_TOKENS,
        &cfg,
        2_000,
        100,
        "wedged",
    );
}

#[test]
fn skip_cs_renew_starves_a_live_holder_and_is_rediscovered() {
    // PR 4 defense: the critical-section path renews the holder's
    // lease (`HandleCache::renew`), so a live holder is never revoked
    // mid-hold. With the renew skipped, a PCT-demoted holder starves
    // past its term, the sweeper fences and relays its lock, and the
    // waiter enters while the oblivious holder is still inside — a
    // mutual-exclusion violation the per-lock oracle catches at entry.
    let _g = serialized();
    let cfg = SimConfig {
        procs: 3,
        locks: 1,
        nodes: 1,
        budget: 4,
        lease_ticks: 12,
        ring_capacity: 8,
        max_steps: 600,
        drain_rounds: 3_000,
        crash_prob: 0.0,
        zombie_prob: 0.0,
        max_crashes: 0,
        manual_arm: false,
        executor_steps: false,
        race_detect: false,
        shared: false,
        mode: SchedMode::Pct { depth: 3 },
    };
    assert_tooth(
        "skip-cs-renew",
        &test_knobs::SKIP_CS_RENEW,
        &cfg,
        2_000,
        150,
        "mutual-exclusion",
    );
}
