//! Integration: AOT artifacts → PJRT load → execute, and the
//! ParamServer on top. Requires `make artifacts` (the Makefile `test`
//! target guarantees it).

use qplock::runtime::{ParamServer, XlaRuntime};

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(&format!("{}/step.hlo.txt", artifacts_dir())).exists()
}

#[test]
fn step_artifact_executes_and_matches_reference_math() {
    if !have_artifacts() {
        panic!("artifacts missing — run `make artifacts` first");
    }
    let rt = XlaRuntime::cpu().unwrap();
    let engine = rt.load(format!("{}/step.hlo.txt", artifacts_dir())).unwrap();

    // S = 0, U = e1 column pattern, V = ones → S' = lr · U·Vᵀ with
    // decay irrelevant (S = 0). aot defaults: decay=0.99, lr=0.05.
    let (m, n, k) = (256usize, 256usize, 8usize);
    let s = vec![0f32; m * n];
    let mut u = vec![0f32; m * k];
    // u row i = [1, 0, 0, ...] so U·Vᵀ = broadcast of V's first column.
    for i in 0..m {
        u[i * k] = 1.0;
    }
    let v = vec![1f32; n * k];
    let outs = engine
        .run_f32(&[
            (&s, &[m as i64, n as i64]),
            (&u, &[m as i64, k as i64]),
            (&v, &[n as i64, k as i64]),
        ])
        .unwrap();
    assert_eq!(outs.len(), 2, "(state, metric)");
    let state = &outs[0];
    assert_eq!(state.len(), m * n);
    for &x in state.iter().take(64) {
        assert!((x - 0.05).abs() < 1e-6, "expected lr*1, got {x}");
    }
    let metric = outs[1][0];
    assert!((metric - 0.05 * 0.05).abs() < 1e-6, "metric {metric}");
}

#[test]
fn apply_artifact_executes() {
    if !have_artifacts() {
        panic!("artifacts missing — run `make artifacts` first");
    }
    let rt = XlaRuntime::cpu().unwrap();
    let engine = rt
        .load(format!("{}/apply.hlo.txt", artifacts_dir()))
        .unwrap();
    let (m, n, c) = (256usize, 256usize, 4usize);
    // S: 2.0 on the diagonal → Y = 2·X.
    let mut s = vec![0f32; m * n];
    for i in 0..m.min(n) {
        s[i * n + i] = 2.0;
    }
    let x: Vec<f32> = (0..n * c).map(|i| (i % 7) as f32).collect();
    let outs = engine
        .run_f32(&[(&s, &[m as i64, n as i64]), (&x, &[n as i64, c as i64])])
        .unwrap();
    let y = &outs[0];
    assert_eq!(y.len(), m * c);
    for i in 0..y.len() {
        assert!((y[i] - 2.0 * x[i]).abs() < 1e-5, "y[{i}]={} x={}", y[i], x[i]);
    }
}

#[test]
fn param_server_converges_like_the_model() {
    if !have_artifacts() {
        panic!("artifacts missing — run `make artifacts` first");
    }
    let rt = XlaRuntime::cpu().unwrap();
    let ps = ParamServer::load(&rt, &artifacts_dir(), Default::default()).unwrap();
    let (u, v) = ps.synth_factors(42);
    // decay = 0.99 → time constant ~100 steps; run well past it.
    let steps = 700;
    let mut metrics = vec![];
    for _ in 0..steps {
        metrics.push(ps.step(&u, &v).unwrap());
    }
    // Approach to the fixed point S* = lr/(1−decay)·UVᵀ: the largest
    // consecutive delta (growth phase) dwarfs the final delta, and the
    // last 50 steps are flat to within 1%.
    let peak_delta = metrics
        .windows(2)
        .map(|w| (w[1] - w[0]).abs())
        .fold(0f32, f32::max);
    let late = (metrics[steps - 1] - metrics[steps - 2]).abs();
    assert!(
        late < 0.01 * peak_delta,
        "no convergence: late {late} peak {peak_delta}"
    );
    let flat = (metrics[steps - 1] - metrics[steps - 50]).abs() / metrics[steps - 1];
    assert!(flat < 0.01, "tail not flat: {flat}");
    assert!(metrics[steps - 1] > 0.0);
    // state_msq readback agrees with the engine's metric.
    assert!((ps.state_msq() - metrics[steps - 1]).abs() / metrics[steps - 1] < 1e-4);
}

#[test]
fn param_server_apply_roundtrip() {
    if !have_artifacts() {
        panic!("artifacts missing — run `make artifacts` first");
    }
    let rt = XlaRuntime::cpu().unwrap();
    let ps = ParamServer::load(&rt, &artifacts_dir(), Default::default()).unwrap();
    let sh = ps.shape();
    let x = vec![1f32; sh.n * sh.c];
    let y0 = ps.apply(&x).unwrap();
    assert!(y0.iter().all(|&v| v == 0.0), "zero state probes to zero");
    let (u, v) = ps.synth_factors(7);
    ps.step(&u, &v).unwrap();
    let y1 = ps.apply(&x).unwrap();
    assert!(y1.iter().any(|&v| v != 0.0), "state updated, probe nonzero");
}
