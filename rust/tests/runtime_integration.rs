//! Integration: the ParamServer over the native compute engine —
//! convergence, probe roundtrips, and the E9 composition shape under a
//! real distributed lock. (Closed-form kernel math is pinned by the
//! unit tests in `runtime/mod.rs`; the JAX oracles in
//! `python/compile/kernels/ref.py` are the cross-language ground
//! truth.)

use qplock::runtime::{ParamServer, ParamShape, XlaRuntime};

#[test]
fn param_server_converges_like_the_model() {
    let rt = XlaRuntime::cpu().unwrap();
    let ps = ParamServer::load(&rt, "unused", Default::default()).unwrap();
    let (u, v) = ps.synth_factors(42);
    // decay = 0.99 → time constant ~100 steps; run well past it.
    let steps = 700;
    let mut metrics = vec![];
    for _ in 0..steps {
        metrics.push(ps.step(&u, &v).unwrap());
    }
    // Approach to the fixed point S* = lr/(1−decay)·UVᵀ: the largest
    // consecutive delta (growth phase) dwarfs the final delta, and the
    // last 50 steps are flat to within 1%.
    let peak_delta = metrics
        .windows(2)
        .map(|w| (w[1] - w[0]).abs())
        .fold(0f32, f32::max);
    let late = (metrics[steps - 1] - metrics[steps - 2]).abs();
    assert!(
        late < 0.01 * peak_delta,
        "no convergence: late {late} peak {peak_delta}"
    );
    let flat = (metrics[steps - 1] - metrics[steps - 50]).abs() / metrics[steps - 1];
    assert!(flat < 0.01, "tail not flat: {flat}");
    assert!(metrics[steps - 1] > 0.0);
    // state_msq readback agrees with the engine's metric.
    assert!((ps.state_msq() - metrics[steps - 1]).abs() / metrics[steps - 1] < 1e-4);
}

#[test]
fn param_server_apply_roundtrip() {
    let rt = XlaRuntime::cpu().unwrap();
    let ps = ParamServer::load(&rt, "unused", Default::default()).unwrap();
    let sh = ps.shape();
    let x = vec![1f32; sh.n * sh.c];
    let y0 = ps.apply(&x).unwrap();
    assert!(y0.iter().all(|&v| v == 0.0), "zero state probes to zero");
    let (u, v) = ps.synth_factors(7);
    ps.step(&u, &v).unwrap();
    let y1 = ps.apply(&x).unwrap();
    assert!(y1.iter().any(|&v| v != 0.0), "state updated, probe nonzero");
}

#[test]
fn param_server_concurrent_steps_fold_exactly() {
    // Four writers (2 local + 2 remote) stepping through qplock — the
    // E9 composition shape. This validates the *engine* under thread
    // concurrency: with decay = 1.0 the fold is order-free, so every
    // update must land exactly once regardless of interleaving. (Lock
    // correctness itself is observed by the runner's CsChecker oracle,
    // not here: ParamServer's internal mutex already serializes engine
    // access, so a broken lock would not corrupt this fold.)
    use qplock::locks::qplock::QpLock;
    use qplock::locks::LockHandle;
    use qplock::rdma::{DomainConfig, RdmaDomain};
    use std::sync::Arc;

    let sh = ParamShape {
        m: 32,
        n: 32,
        k: 2,
        c: 1,
        decay: 1.0, // no forgetting → final state = lr · Σ U·Vᵀ, order-free
        lr: 0.5,
    };
    let ps = Arc::new(ParamServer::new(sh));
    let d = RdmaDomain::new(2, 1 << 14, DomainConfig::counted());
    let lock = QpLock::create(&d, 0, 4);
    let steps_per_writer = 50u64;
    let mut ts = vec![];
    for node in [0u16, 0, 1, 1] {
        let mut h = lock.qp_handle(d.endpoint(node));
        let ps = Arc::clone(&ps);
        ts.push(std::thread::spawn(move || {
            let u = vec![1f32; sh.m * sh.k];
            let v = vec![1f32; sh.n * sh.k];
            for _ in 0..steps_per_writer {
                h.lock();
                ps.step(&u, &v).unwrap();
                h.unlock();
            }
        }));
    }
    for t in ts {
        t.join().unwrap();
    }
    // Each step adds lr·(U·Vᵀ) = 0.5·2 = 1.0 to every entry; 200 steps.
    let expect = (4 * steps_per_writer) as f32;
    let x = vec![1f32; sh.n * sh.c];
    let y = ps.apply(&x).unwrap();
    for &yi in &y {
        assert!(
            (yi - expect * sh.n as f32).abs() < 1e-2 * expect,
            "probe {yi}, expected {}",
            expect * sh.n as f32
        );
    }
}
