//! Integration proof of the ordering-contract static pass (TESTING.md
//! Layer 5): the shipped tree lints clean under `hb-lint`, and each
//! seeded violation fixture is flagged at its exact `file:line`.
//!
//! The fixtures live under `tests/fixtures/hb_lint/` — a directory
//! cargo does not compile — so each one can contain exactly the
//! ordering hazard the lint must reject.

use std::fs;
use std::path::PathBuf;

use qplock::analysis::hb_lint::{lint_source, lint_tree};
use qplock::analysis::Diagnostic;

fn fixture(name: &str) -> String {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/hb_lint")
        .join(name);
    match fs::read_to_string(&p) {
        Ok(s) => s,
        Err(e) => panic!("{}: {e}", p.display()),
    }
}

fn lint_fixture(name: &str) -> Vec<Diagnostic> {
    // Fixtures model qplock protocol code, so they are linted under
    // the protocol file's name: the anchors keyed to it apply.
    lint_source("locks/qplock.rs", &fixture(name))
}

fn flagged(diags: &[Diagnostic], rule: &str, line: u32) -> bool {
    diags.iter().any(|d| d.rule == rule && d.line == line)
}

#[test]
fn clean_tree_lints_clean() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let diags = lint_tree(&src).expect("source tree must be readable");
    assert!(diags.is_empty(), "the tree must hb-lint clean:\n{diags:#?}");
}

#[test]
fn dropped_recheck_fixture_is_flagged_at_line_9() {
    let d = lint_fixture("dropped_recheck.rs");
    assert!(flagged(&d, "hb-dropped-recheck", 9), "{d:#?}");
}

#[test]
fn relaxed_gate_fixture_is_flagged_at_line_9() {
    let d = lint_fixture("relaxed_gate.rs");
    assert!(flagged(&d, "hb-relaxed-ordering", 9), "{d:#?}");
}

#[test]
fn reversed_publish_fixture_is_flagged_at_line_6() {
    let d = lint_fixture("reversed_publish.rs");
    assert!(flagged(&d, "hb-order", 6), "{d:#?}");
}

#[test]
fn unregistered_edge_fixture_is_flagged_at_line_6() {
    let d = lint_fixture("unregistered_edge.rs");
    assert!(flagged(&d, "hb-unregistered-edge", 6), "{d:#?}");
}

/// The fixtures seed exactly one hazard each: no fixture may trip a
/// second rule, or the pinned line above could be masking a
/// false positive elsewhere in the file.
#[test]
fn each_fixture_raises_exactly_one_diagnostic() {
    for name in [
        "dropped_recheck.rs",
        "relaxed_gate.rs",
        "reversed_publish.rs",
        "unregistered_edge.rs",
    ] {
        let d = lint_fixture(name);
        assert_eq!(d.len(), 1, "{name} must raise exactly one:\n{d:#?}");
    }
}
