//! Cross-module lock integration: every algorithm × several topologies
//! under the coordinator runner, with the mutual-exclusion oracle, the
//! paper's op-count claims, and fairness behavior.

use std::sync::Arc;
use std::time::Duration;

use qplock::coordinator::{run_workload, Cluster, CsWork, Workload};
use qplock::locks::{make_lock, Class, ALGORITHMS};
use qplock::rdma::{AtomicityMode, DomainConfig};

fn counted_cluster(nodes: u16) -> Cluster {
    Cluster::new(nodes, 1 << 18, DomainConfig::counted())
}

#[test]
fn all_correct_algorithms_pass_three_node_stress() {
    for algo in ALGORITHMS {
        if *algo == "naive-mixed" {
            continue;
        }
        let c = counted_cluster(3);
        let lock = make_lock(algo, &c.domain, 0, 6, 4);
        // 2 local + 4 remote split over two remote nodes.
        let procs = c.spread_procs(6, 2, 0);
        let r = run_workload(&c.domain, &lock, &procs, &Workload::cycles(250));
        assert_eq!(r.violations, 0, "{algo}");
        assert_eq!(r.total_acquisitions(), 1500, "{algo}");
    }
}

#[test]
fn all_correct_algorithms_pass_under_timed_fabric() {
    for algo in ALGORITHMS {
        if *algo == "naive-mixed" {
            continue;
        }
        let c = Cluster::new(2, 1 << 18, DomainConfig::fast_timed());
        let lock = make_lock(algo, &c.domain, 0, 4, 4);
        let procs = c.spread_procs(4, 2, 0);
        let r = run_workload(&c.domain, &lock, &procs, &Workload::cycles(120));
        assert_eq!(r.violations, 0, "{algo}");
    }
}

#[test]
fn qplock_local_class_stays_off_the_nic_in_every_topology() {
    for (nodes, nprocs, nlocal) in [(2u16, 4u32, 2u32), (3, 9, 3), (2, 2, 1), (4, 8, 0)] {
        let c = counted_cluster(nodes);
        let lock = make_lock("qplock", &c.domain, 0, nprocs, 8);
        let procs = c.spread_procs(nprocs, nlocal, 0);
        let r = run_workload(&c.domain, &lock, &procs, &Workload::cycles(200));
        assert_eq!(r.violations, 0);
        for p in &r.procs {
            if p.class == Class::Local {
                assert_eq!(
                    p.ops.remote_total(),
                    0,
                    "local pid {} issued RDMA ({nodes} nodes)",
                    p.pid
                );
                assert_eq!(p.ops.loopback, 0);
            }
        }
    }
}

#[test]
fn qplock_remote_ops_stay_constant_as_contention_grows() {
    // The paper's O(1)-remote-verbs property: per-acquisition remote op
    // count for remote processes must not scale with process count
    // (contrast: filter/bakery scale linearly).
    let mut per_acq = vec![];
    for nprocs in [2u32, 4, 8] {
        let c = counted_cluster(2);
        let lock = make_lock("qplock", &c.domain, 0, nprocs, 8);
        let procs = c.spread_procs(nprocs, nprocs / 2, 0);
        let r = run_workload(&c.domain, &lock, &procs, &Workload::cycles(300));
        assert_eq!(r.violations, 0);
        let remote_ops: u64 = r
            .procs
            .iter()
            .filter(|p| p.class == Class::Remote)
            .map(|p| p.ops.remote_total())
            .sum();
        let remote_acq: u64 = r
            .procs
            .iter()
            .filter(|p| p.class == Class::Remote)
            .map(|p| p.acquisitions)
            .sum();
        per_acq.push(remote_ops as f64 / remote_acq as f64);
    }
    // Allow protocol noise, forbid linear growth.
    assert!(
        per_acq[2] < per_acq[0] * 3.0,
        "remote verbs/acq grew with contention: {per_acq:?}"
    );
}

#[test]
fn filter_lock_remote_ops_scale_with_max_procs() {
    // The anti-property the paper criticizes.
    let mut per_acq = vec![];
    for nprocs in [2u32, 8] {
        let c = counted_cluster(2);
        let lock = make_lock("filter", &c.domain, 0, nprocs, 8);
        // Lone process measurement: isolation cost.
        let ep = c.domain.endpoint(1);
        let m = Arc::clone(&ep.metrics);
        let mut h = lock.handle(ep, 0);
        for _ in 0..50 {
            h.lock();
            h.unlock();
        }
        per_acq.push(m.snapshot().remote_total() as f64 / 50.0);
    }
    assert!(
        per_acq[1] > per_acq[0] * 4.0,
        "filter should scale with n: {per_acq:?}"
    );
}

#[test]
fn naive_mixed_is_fine_with_global_atomics_and_broken_without() {
    // Global atomicity: clean.
    let c = Cluster::new(
        2,
        1 << 16,
        DomainConfig::counted().with_atomicity(AtomicityMode::Global),
    );
    let lock = make_lock("naive-mixed", &c.domain, 0, 4, 8);
    let procs = c.spread_procs(4, 2, 0);
    let r = run_workload(&c.domain, &lock, &procs, &Workload::cycles(500));
    assert_eq!(r.violations, 0);

    // Commodity atomicity with a widened NIC window: violations appear.
    // (Deterministic demonstration lives in the unit test and the model
    // checker; here we only require the runner to *survive* it.)
    let c = Cluster::new(
        2,
        1 << 16,
        DomainConfig::counted()
            .with_atomicity(AtomicityMode::NicSerialized)
            .with_hazard_ns(200_000),
    );
    let lock = make_lock("naive-mixed", &c.domain, 0, 4, 8);
    let procs = c.spread_procs(4, 2, 0);
    let r = run_workload(&c.domain, &lock, &procs, &Workload::cycles(200));
    // Violations may or may not land in a short run; the harness must
    // report them rather than crash.
    let _ = r.violations;
}

#[test]
fn budget_one_equalizes_classes() {
    // Small budget forces frequent global handoffs: neither class can
    // monopolize. With CS work, both classes should get within 4x of
    // each other's acquisition counts.
    let c = Cluster::new(2, 1 << 18, DomainConfig::fast_timed());
    let lock = make_lock("qplock", &c.domain, 0, 6, 1);
    let procs = c.spread_procs(6, 3, 0);
    let wl = Workload::timed(Duration::from_millis(150), CsWork::SpinNs(2_000));
    let r = run_workload(&c.domain, &lock, &procs, &wl);
    assert_eq!(r.violations, 0);
    let (l, rm) = r.class_split();
    assert!(l > 0 && rm > 0, "both classes progress: {l}/{rm}");
    let ratio = l.max(rm) as f64 / l.min(rm).max(1) as f64;
    assert!(ratio < 4.0, "budget=1 should equalize: local {l} remote {rm}");
}

#[test]
fn guard_raii_releases() {
    use qplock::locks::Guard;
    let c = counted_cluster(2);
    let lock = make_lock("qplock", &c.domain, 0, 2, 8);
    let mut h1 = lock.handle(c.domain.endpoint(0), 0);
    let mut h2 = lock.handle(c.domain.endpoint(1), 1);
    {
        let _g = Guard::acquire(h1.as_mut());
        // dropped here
    }
    // If the guard failed to unlock, this would deadlock (test timeout).
    h2.lock();
    h2.unlock();
}
