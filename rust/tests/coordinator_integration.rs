//! Coordinator-layer integration: the lock service under concurrent
//! multi-shard load, workload think times, duration mode, and the
//! experiment harness end to end.

use std::sync::Arc;
use std::time::Duration;

use qplock::bench::{run_experiment, Scale};
use qplock::coordinator::{
    run_multi_lock_workload, run_workload, Cluster, CsWork, LockService, Workload,
};
use qplock::locks::make_lock;
use qplock::rdma::DomainConfig;

#[test]
fn ten_thousand_lock_zipfian_sweep_is_clean() {
    // The tentpole acceptance run: a 10k-named-lock table, Zipfian
    // draws, processes on 3 nodes, per-lock mutual-exclusion oracles —
    // zero violations, and local-class qplock handles end the sweep
    // with zero remote verbs.
    let cluster = Cluster::new(3, 1 << 21, DomainConfig::counted());
    let svc = Arc::new(LockService::new(&cluster.domain, "qplock", 8));
    let procs = cluster.round_robin_procs(6);
    let wl = Workload::cycles(300).with_locks(10_000, 0.99).with_seed(0xA110C);
    let r = run_multi_lock_workload(&svc, &procs, &wl);
    assert_eq!(r.violations, 0, "mutual exclusion violated");
    assert_eq!(r.total_acquisitions(), 6 * 300);
    assert_eq!(svc.len(), 10_000, "whole table registered");
    assert!(r.locks_touched() > 100, "zipf tail unexplored");
    assert_eq!(
        r.local_class_remote_verbs(),
        0,
        "local-class handles must end the sweep NIC-clean"
    );
    // Skew showed up: the hottest lock got a clear plurality.
    assert!(r.hottest_share() > 0.03, "share {}", r.hottest_share());
    // Handle caching did its job: minted handles ≪ acquisitions.
    let minted: u64 = r.procs.iter().map(|p| p.cache_misses).sum();
    assert!(minted < r.total_acquisitions(), "no reuse happened");
}

#[test]
fn service_multi_shard_concurrent_clients() {
    let cluster = Cluster::new(3, 1 << 18, DomainConfig::counted());
    let svc = Arc::new(LockService::new(&cluster.domain, "qplock", 8));
    let shards = ["a", "b", "c", "d"];
    for s in &shards {
        svc.ensure_lock(s);
    }
    let hits = Arc::new(
        (0..shards.len())
            .map(|_| std::sync::atomic::AtomicU64::new(0))
            .collect::<Vec<_>>(),
    );
    let mut ts = vec![];
    for node in 0..3u16 {
        for _ in 0..2 {
            let svc = Arc::clone(&svc);
            let hits = Arc::clone(&hits);
            ts.push(std::thread::spawn(move || {
                let mut handles: Vec<_> = shards
                    .iter()
                    .map(|s| svc.client(s, node).expect("mint client"))
                    .collect();
                for _ in 0..100 {
                    for (i, h) in handles.iter_mut().enumerate() {
                        h.lock();
                        let v = hits[i].load(std::sync::atomic::Ordering::Relaxed);
                        hits[i].store(v + 1, std::sync::atomic::Ordering::Relaxed);
                        h.unlock();
                    }
                }
            }));
        }
    }
    for t in ts {
        t.join().unwrap();
    }
    for h in hits.iter() {
        assert_eq!(h.load(std::sync::atomic::Ordering::Relaxed), 600);
    }
    assert_eq!(svc.registry().len(), 4);
}

#[test]
fn mixed_algorithms_in_one_service() {
    let cluster = Cluster::new(2, 1 << 16, DomainConfig::counted());
    let svc = LockService::new(&cluster.domain, "qplock", 8);
    svc.create_lock("q", "qplock", 0, 4, 8).unwrap();
    svc.create_lock("m", "rdma-mcs", 1, 4, 8).unwrap();
    svc.create_lock("r", "rpc-server", 0, 4, 8).unwrap();
    for name in ["q", "m", "r"] {
        let mut h = svc.client(name, 1).unwrap();
        h.lock();
        h.unlock();
    }
    let reg = svc.registry();
    assert_eq!(reg.len(), 3);
    assert!(reg.iter().any(|(n, _, a)| n == "m" && *a == "rdma-mcs"));
}

#[test]
fn think_times_reduce_contention_but_preserve_counts() {
    let c = Cluster::new(2, 1 << 16, DomainConfig::counted());
    let lock = make_lock("qplock", &c.domain, 0, 4, 8);
    let procs = c.spread_procs(4, 2, 0);
    let wl = Workload::cycles(100).with_think_ns(20_000).with_seed(99);
    let r = run_workload(&c.domain, &lock, &procs, &wl);
    assert_eq!(r.total_acquisitions(), 400);
    assert_eq!(r.violations, 0);
}

#[test]
fn cs_payload_spin_is_reflected_in_cycle_latency() {
    let c = Cluster::new(2, 1 << 16, DomainConfig::counted());
    let lock = make_lock("qplock", &c.domain, 0, 2, 8);
    let procs = c.spread_procs(2, 1, 0);
    let wl = Workload::cycles(100).with_cs(CsWork::SpinNs(50_000));
    let r = run_workload(&c.domain, &lock, &procs, &wl);
    for p in &r.procs {
        assert!(
            p.cycle_ns.p50() >= 40_000,
            "CS spin not visible: p50={}",
            p.cycle_ns.p50()
        );
    }
}

#[test]
fn duration_mode_window_is_common() {
    let c = Cluster::new(2, 1 << 16, DomainConfig::counted());
    let lock = make_lock("qplock", &c.domain, 0, 4, 8);
    let procs = c.spread_procs(4, 2, 0);
    let wl = Workload::timed(Duration::from_millis(60), CsWork::None);
    let r = run_workload(&c.domain, &lock, &procs, &wl);
    assert!(r.wall < Duration::from_secs(8));
    assert!(r.total_acquisitions() > 0);
}

#[test]
fn experiment_harness_e2_and_e8_run_end_to_end() {
    // These two are deterministic (counted mode / model checking) and
    // fast; they pin the harness plumbing.
    let out = run_experiment("e2", Scale::Quick);
    assert_eq!(out.tables.len(), 1);
    assert!(out.tables[0].rows() >= 6);
    let out = run_experiment("e8", Scale::Quick);
    assert!(out.tables[0].rows() >= 5);
}

#[test]
fn experiment_e5_budget_sweep_shape() {
    let out = run_experiment("e5", Scale::Quick);
    let t = &out.tables[0];
    assert!(t.rows() >= 2);
    // Jain column parses as a probability.
    for r in 0..t.rows() {
        let jain: f64 = t.cell(r, 2).parse().unwrap();
        assert!((0.0..=1.0).contains(&jain));
    }
}
