//! Model-checker integration: the E8 battery as assertions — the
//! paper's verification claims, reproduced end to end.

use qplock::mc::models::{
    naive_spec::NaiveSpec, peterson_spec::PetersonSpec, qplock_spec::QpSpec,
    spin_spec::SpinSpec,
};
use qplock::mc::{check_all, graph::explore};

#[test]
fn qplock_battery_matches_paper_for_all_small_configs() {
    for (n, b) in [(2usize, 1u8), (2, 2), (3, 1), (3, 2)] {
        let r = check_all(&QpSpec::new(n, b), 1 << 22);
        assert!(!r.truncated, "n={n} B={b} truncated at {} states", r.states);
        assert!(r.mutual_exclusion.holds(), "ME n={n} B={b}");
        assert!(r.deadlock_free.holds(), "deadlock n={n} B={b}");
        assert!(r.starvation_free.holds(), "starvation n={n} B={b}");
        assert!(r.dead_and_livelock_free.holds(), "livelock n={n} B={b}");
    }
}

#[test]
fn qplock_state_space_grows_with_procs_and_budget() {
    let s21 = check_all(&QpSpec::new(2, 1), 1 << 22).states;
    let s31 = check_all(&QpSpec::new(3, 1), 1 << 22).states;
    let s32 = check_all(&QpSpec::new(3, 2), 1 << 22).states;
    assert!(s31 > s21 * 4, "{s21} -> {s31}");
    assert!(s32 > s31, "{s31} -> {s32}");
}

#[test]
fn naive_spec_counterexample_is_the_paper_interleaving() {
    let r = explore(&NaiveSpec, 1 << 16);
    let vid = r.me_violation.expect("violation must exist");
    let trace = r.graph.trace_to(vid);
    // Shortest counterexample: init, p2 ncs->try, p2 try(read 0),
    // p1 ncs->try, p1 try(cas wins -> cs), p2 commit(stale) -> both cs.
    // Exact step order may interleave ncs steps differently but the
    // length is tightly bounded.
    assert!(trace.len() >= 5 && trace.len() <= 7, "len {}", trace.len());
}

#[test]
fn peterson_and_spin_checker_cross_validation() {
    // Peterson: everything holds. Spin TAS: safety holds, fairness
    // fails. This cross-validates the liveness analysis in both
    // directions on textbook algorithms.
    let p = check_all(&PetersonSpec, 1 << 18);
    assert!(p.mutual_exclusion.holds() && p.starvation_free.holds());
    for n in [2, 3, 4] {
        let s = check_all(&SpinSpec::new(n), 1 << 20);
        assert!(s.mutual_exclusion.holds(), "n={n}");
        assert!(s.deadlock_free.holds(), "n={n}");
        assert!(!s.starvation_free.holds(), "n={n}: TAS must starve");
        assert!(s.dead_and_livelock_free.holds(), "n={n}: but not livelock");
    }
}

#[test]
fn truncation_is_reported_not_silent() {
    let r = check_all(&QpSpec::new(3, 2), 100);
    assert!(r.truncated);
    assert!(!r.starvation_free.holds()); // Unknown, not Holds
    assert!(r.states >= 100);
}
