//! Ready-list wakeup subsystem: property tests over random
//! submit/poll/cancel/release schedules, the O(ready) poll-work bound
//! at 10k parked waiters, and the verb accounting of armed waiting.
//!
//! Invariants covered (ISSUE 3 acceptance):
//! * **No lost wakeups** — with the fallback sweep disabled, armed
//!   acquisitions are polled *only* when their ring token is consumed;
//!   every random schedule still completing proves each handoff's
//!   wakeup arrives (or the arm-time re-check caught the race).
//! * **O(ready) poll work** — a session with 10k parked waiters
//!   performs O(1) handle polls per `poll_ready` round after a single
//!   release (scan mode: O(pending)), counted by session
//!   instrumentation.
//! * **Zero remote verbs for parked polls still holds** — idle ready
//!   rounds (ring consumption included) never touch the NIC, and the
//!   wakeup publication keeps handoffs at O(1) remote verbs.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use qplock::coordinator::{ready_list_probe, Cluster, HandleCache, LockService, PollMode};
use qplock::locks::LockPoll;
use qplock::rdma::DomainConfig;
use qplock::util::prng::Prng;

#[test]
fn ten_k_parked_waiters_one_release_is_o1_polls_per_round() {
    // The instrumented acceptance bound: 10k parked waiters, 1 release
    // ⇒ O(1) handle polls in ready mode vs O(N) in scan mode.
    let k = 10_000u32;
    let ready = ready_list_probe(k, 1, PollMode::Ready);
    assert!(
        ready.handle_polls <= 4,
        "ready mode polled {} handles for one release at K={k}",
        ready.handle_polls
    );
    let scan = ready_list_probe(k, 1, PollMode::Scan);
    assert!(
        scan.handle_polls >= k as u64,
        "scan mode should touch every parked waiter: {} polls",
        scan.handle_polls
    );
}

#[test]
fn armed_remote_waiters_idle_rounds_are_nic_silent_and_handoffs_stay_o1() {
    // Locks homed on node 0, both sessions on node 1: remote class,
    // shared cohort, so every waiter parks in the armable budget-wait.
    let cycles = 16u32;
    let cluster = Cluster::new(2, 1 << 18, DomainConfig::counted());
    let svc = Arc::new(LockService::new(&cluster.domain, "qplock", 8).with_default_max_procs(2));
    let names: Vec<String> = (0..cycles).map(|i| format!("ow-{i}")).collect();
    for n in &names {
        svc.create_lock(n, "qplock", 0, 2, 8).unwrap();
    }
    let mut holder = svc.session(1);
    for n in &names {
        assert_eq!(holder.submit(n).unwrap(), LockPoll::Held);
    }
    let mut waiter = svc.session(1);
    waiter.enable_ready_wakeups(32);
    waiter.set_sweep_interval(0);
    for n in &names {
        assert_eq!(waiter.submit(n).unwrap(), LockPoll::Pending);
    }
    while waiter.armed_count() < names.len() {
        assert!(waiter.poll_ready().is_empty());
    }

    // (c) Parked polling is free: 1000 idle ready rounds issue zero
    // handle polls and zero remote verbs (ring consumption is local).
    let polls0 = waiter.handle_polls();
    let before = waiter.remote_class_metrics().snapshot();
    for _ in 0..1_000 {
        assert!(waiter.poll_ready().is_empty());
    }
    assert_eq!(waiter.handle_polls() - polls0, 0);
    let idle = waiter.remote_class_metrics().snapshot() - before;
    assert_eq!(idle.remote_total(), 0, "idle ready rounds used the NIC");

    // Drain, then check O(1) remote verbs per acquisition for BOTH
    // sides — the wakeup publication (ring-header read, slot claim,
    // slot write) rides the handoff at constant cost.
    for n in &names {
        holder.release(n);
    }
    let mut done = 0;
    while done < names.len() {
        for n in waiter.poll_ready() {
            waiter.release(&n);
            done += 1;
        }
    }
    let w = waiter.remote_class_metrics().snapshot();
    let h = holder.remote_class_metrics().snapshot();
    let per_w = w.remote_total() as f64 / cycles as f64;
    let per_h = h.remote_total() as f64 / cycles as f64;
    assert!(per_w <= 8.0, "waiter remote verbs/acq too high: {per_w}");
    assert!(per_h <= 12.0, "holder remote verbs/acq too high: {per_h}");
}

/// Random single-threaded schedules over several ready-mode sessions:
/// submits, ready polls, cancels, and releases in random order, with
/// the fallback sweep disabled so armed names resolve *only* through
/// their tokens. Completion of every schedule within the step budget
/// is the no-lost-wakeup proof; a global owner map is the
/// mutual-exclusion oracle.
#[test]
fn prop_random_schedules_complete_on_wakeups_alone() {
    for seed in 0..12u64 {
        let mut rng = Prng::seed_from(0x3A11 ^ seed.wrapping_mul(0x9E3779B9));
        let nodes = 2 + rng.below(2) as u16;
        let cluster = Cluster::new(nodes, 1 << 18, DomainConfig::counted());
        let nsessions = 2 + rng.below(3) as usize;
        let budget = 1 + rng.below(4);
        let svc = Arc::new(
            LockService::new(&cluster.domain, "qplock", budget)
                .with_default_max_procs(nsessions as u32),
        );
        let nlocks = 1 + rng.below(5) as usize;
        let names: Vec<String> = (0..nlocks).map(|i| format!("rs-{i}")).collect();
        let mut sessions: Vec<HandleCache> = (0..nsessions)
            .map(|i| {
                let mut s = svc.session((i as u16) % nodes);
                s.enable_ready_wakeups(16);
                s.set_sweep_interval(0);
                s
            })
            .collect();
        let mut held: Vec<HashSet<String>> = vec![HashSet::new(); nsessions];
        let mut owner: HashMap<String, usize> = HashMap::new();
        let mut completed = vec![0u64; nsessions];
        let target = 25u64;
        let total_target = target * nsessions as u64;
        let claim = |owner: &mut HashMap<String, usize>, name: &str, who: usize| {
            let prev = owner.insert(name.to_string(), who);
            assert!(
                prev.is_none(),
                "seed {seed}: ME violated on '{name}': {who} vs {prev:?}"
            );
        };
        let mut steps = 0u64;
        while completed.iter().sum::<u64>() < total_target {
            steps += 1;
            assert!(
                steps < 2_000_000,
                "seed {seed}: no progress — lost wakeup? completed {completed:?}"
            );
            let i = rng.below(nsessions as u64) as usize;
            match rng.below(10) {
                0..=3 => {
                    // Submit a name this session neither holds nor has
                    // in flight.
                    if completed[i] >= target {
                        continue;
                    }
                    let n = &names[rng.below(nlocks as u64) as usize];
                    if held[i].contains(n) || sessions[i].is_pending(n) {
                        continue;
                    }
                    if sessions[i].submit(n).unwrap() == LockPoll::Held {
                        claim(&mut owner, n, i);
                        held[i].insert(n.clone());
                        completed[i] += 1;
                    }
                }
                4..=7 => {
                    for n in sessions[i].poll_ready() {
                        claim(&mut owner, &n, i);
                        held[i].insert(n);
                        completed[i] += 1;
                    }
                }
                8 => {
                    if let Some(n) = held[i].iter().next().cloned() {
                        held[i].remove(&n);
                        owner.remove(&n);
                        sessions[i].release(&n);
                    }
                }
                _ => {
                    // Cancel a random in-flight acquisition: either it
                    // detaches now or it drains through its token.
                    let pending = sessions[i].pending_names();
                    if let Some(n) = pending.first() {
                        sessions[i].cancel(n);
                    }
                }
            }
        }
        // Drain so every handle is idle before the sessions drop.
        let mut guard = 0u64;
        loop {
            guard += 1;
            assert!(guard < 500_000, "seed {seed}: drain stuck");
            let mut open = false;
            for i in 0..nsessions {
                let got = sessions[i].poll_ready();
                for n in got {
                    claim(&mut owner, &n, i);
                    held[i].insert(n);
                }
                let hs: Vec<String> = held[i].drain().collect();
                for n in &hs {
                    owner.remove(n);
                    sessions[i].release(n);
                }
                if sessions[i].pending_count() > 0 {
                    open = true;
                }
            }
            if !open {
                break;
            }
        }
    }
}
