//! Ready-list wakeup subsystem: seeded deterministic explorer runs
//! over submit/poll/arm/cancel/release schedules (see `qplock::sim`
//! and TESTING.md), the O(ready) poll-work bound at 10k parked
//! waiters, the verb accounting of armed waiting, and one threaded
//! smoke test of the ready scheduler.
//!
//! Invariants covered (ISSUE 3 acceptance):
//! * **No lost wakeups** — with the fallback sweep disabled, armed
//!   acquisitions are polled *only* when their ring token is consumed;
//!   every explored schedule's drain converging proves each handoff's
//!   wakeup arrives (or the arm-time re-check caught the race).
//! * **O(ready) poll work** — a session with 10k parked waiters
//!   performs O(1) handle polls per `poll_ready` round after a single
//!   release (scan mode: O(pending)), counted by session
//!   instrumentation.
//! * **Zero remote verbs for parked polls still holds** — idle ready
//!   rounds (ring consumption included) never touch the NIC, and the
//!   wakeup publication keeps handoffs at O(1) remote verbs.

use std::sync::Arc;

use qplock::coordinator::{
    ready_list_probe, run_multiplexed_workload_mode, Cluster, LockService, PollMode, Workload,
};
use qplock::locks::LockPoll;
use qplock::rdma::DomainConfig;
use qplock::sim::{run_one, SchedMode, SimConfig};

#[test]
fn ten_k_parked_waiters_one_release_is_o1_polls_per_round() {
    // The instrumented acceptance bound: 10k parked waiters, 1 release
    // ⇒ O(1) handle polls in ready mode vs O(N) in scan mode.
    let k = 10_000u32;
    let ready = ready_list_probe(k, 1, PollMode::Ready);
    assert!(
        ready.handle_polls <= 4,
        "ready mode polled {} handles for one release at K={k}",
        ready.handle_polls
    );
    let scan = ready_list_probe(k, 1, PollMode::Scan);
    assert!(
        scan.handle_polls >= k as u64,
        "scan mode should touch every parked waiter: {} polls",
        scan.handle_polls
    );
}

#[test]
fn armed_remote_waiters_idle_rounds_are_nic_silent_and_handoffs_stay_o1() {
    // Locks homed on node 0, both sessions on node 1: remote class,
    // shared cohort, so every waiter parks in the armable budget-wait.
    let cycles = 16u32;
    let cluster = Cluster::new(2, 1 << 18, DomainConfig::counted());
    let svc = Arc::new(LockService::new(&cluster.domain, "qplock", 8).with_default_max_procs(2));
    let names: Vec<String> = (0..cycles).map(|i| format!("ow-{i}")).collect();
    for n in &names {
        svc.create_lock(n, "qplock", 0, 2, 8).unwrap();
    }
    let mut holder = svc.session(1);
    for n in &names {
        assert_eq!(holder.submit(n).unwrap(), LockPoll::Held);
    }
    let mut waiter = svc.session(1);
    waiter.enable_ready_wakeups(32);
    waiter.set_sweep_interval(0);
    for n in &names {
        assert_eq!(waiter.submit(n).unwrap(), LockPoll::Pending);
    }
    while waiter.armed_count() < names.len() {
        assert!(waiter.poll_ready().is_empty());
    }

    // (c) Parked polling is free: 1000 idle ready rounds issue zero
    // handle polls and zero remote verbs (ring consumption is local).
    let polls0 = waiter.handle_polls();
    let before = waiter.remote_class_metrics().snapshot();
    for _ in 0..1_000 {
        assert!(waiter.poll_ready().is_empty());
    }
    assert_eq!(waiter.handle_polls() - polls0, 0);
    let idle = waiter.remote_class_metrics().snapshot() - before;
    assert_eq!(idle.remote_total(), 0, "idle ready rounds used the NIC");

    // Drain, then check O(1) remote verbs per acquisition for BOTH
    // sides — the wakeup publication (ring-header read, slot claim,
    // slot write) rides the handoff at constant cost.
    for n in &names {
        holder.release(n).unwrap();
    }
    let mut done = 0;
    while done < names.len() {
        for n in waiter.poll_ready() {
            waiter.release(&n).unwrap();
            done += 1;
        }
    }
    let w = waiter.remote_class_metrics().snapshot();
    let h = holder.remote_class_metrics().snapshot();
    let per_w = w.remote_total() as f64 / cycles as f64;
    let per_h = h.remote_total() as f64 / cycles as f64;
    assert!(per_w <= 8.0, "waiter remote verbs/acq too high: {per_w}");
    assert!(per_h <= 12.0, "holder remote verbs/acq too high: {per_h}");
}

#[test]
fn revoked_waiters_published_token_is_discarded_not_delivered() {
    // Lease/ring interaction (ISSUE 4 satellite): the handoff's token
    // was published for an armed waiter, and the waiter's acquisition
    // is then revoked before it consumes it. `poll_ready` must discard
    // the token via the stale-epoch cross-check (the poll surfaces
    // Expired) — never report the revoked acquisition as held.
    let cluster = Cluster::new(2, 1 << 18, DomainConfig::counted());
    let svc = Arc::new(
        LockService::new(&cluster.domain, "qplock", 8)
            .with_default_max_procs(4)
            .with_lease_ticks(50),
    );
    svc.create_lock("rv", "qplock", 0, 4, 8).unwrap();
    let mut holder = svc.session(1);
    assert_eq!(holder.submit("rv").unwrap(), LockPoll::Held);
    let mut w = svc.session(1);
    w.enable_ready_wakeups(4);
    w.set_sweep_interval(0);
    w.set_lease_heartbeat(0); // the waiter is about to "die"
    assert_eq!(w.submit("rv").unwrap(), LockPoll::Pending);
    while !w.is_armed("rv") {
        assert!(w.poll_ready().is_empty());
    }
    // The holder releases while the waiter is armed and alive-looking:
    // the token IS published into the waiter's ring.
    holder.release("rv").unwrap();
    assert!(w.handoff_arrived("rv"), "budget landed, token in the ring");
    // The waiter stalls past its lease; the sweeper revokes it and
    // clears the abandoned tail (the handoff had already arrived).
    let now = cluster.domain.advance_lease_clock(500);
    let stats = svc.sweep_leases(now);
    assert_eq!(stats.fenced, 1);
    assert_eq!(stats.released, 1, "abandoned lock freed");
    // The zombie session wakes and drains its ring: the token must be
    // discarded — the poll observes the fence, nothing is held.
    for _ in 0..10 {
        assert!(
            w.poll_ready().is_empty(),
            "a revoked acquisition was reported held off a stale token"
        );
    }
    assert_eq!(w.take_expired(), vec!["rv".to_string()]);
    assert_eq!(w.pending_count(), 0);
    assert_eq!(w.release("rv"), Err(qplock::locks::LeaseError::Expired));
    // The lock is free for anyone (the revoke freed it, the zombie's
    // stale token did not resurrect it).
    let mut fresh = svc.session(0);
    assert_eq!(fresh.submit("rv").unwrap(), LockPoll::Held);
    fresh.release("rv").unwrap();
}

#[test]
fn ten_k_armed_lease_holders_keep_o1_rounds_and_never_expire() {
    // The 10k-waiter O(1) invariant, restated under leases: with 10k
    // armed (unpolled) waiters on lease-enabled locks, the session
    // heartbeat keeps every lease alive — repeated sweeps at an
    // advancing clock revoke nothing — while idle ready rounds still
    // issue ZERO handle polls (renewals are not polls; the O(ready)
    // property survives the lease layer).
    let k = 10_000u32;
    let ticks = 50u64;
    let words = (64u64 * k as u64 + (1 << 16)).min(u32::MAX as u64) as u32;
    let cluster = Cluster::new(2, words, DomainConfig::counted());
    let svc = Arc::new(
        LockService::new(&cluster.domain, "qplock", 8)
            .with_default_max_procs(2)
            .with_lease_ticks(ticks),
    );
    let names: Vec<String> = (0..k).map(|i| format!("lk{i:06}")).collect();
    for n in &names {
        svc.create_lock(n, "qplock", 0, 2, 8).unwrap();
    }
    let mut holder = svc.session(1);
    for n in &names {
        assert_eq!(holder.submit(n).unwrap(), LockPoll::Held);
    }
    let mut w = svc.session(1);
    w.enable_ready_wakeups(k);
    w.set_sweep_interval(0);
    w.set_lease_heartbeat(1);
    for n in &names {
        assert_eq!(w.submit(n).unwrap(), LockPoll::Pending);
    }
    let mut rounds = 0;
    while w.armed_count() < k as usize {
        assert!(w.poll_ready().is_empty());
        rounds += 1;
        assert!(rounds < 64, "waiters failed to park and arm");
    }
    // Steady state: clock advances in sub-term steps, the heartbeat
    // renews all 10k armed leases each round, sweeps find everything
    // alive, and no handle is ever polled. The holder renews its 10k
    // held leases explicitly (its own heartbeat path).
    let polls0 = w.handle_polls();
    for _ in 0..20 {
        cluster.domain.advance_lease_clock(ticks / 2);
        for n in &names {
            holder.renew(n).unwrap();
        }
        assert!(w.poll_ready().is_empty());
        let stats = svc.sweep_leases(cluster.domain.lease_now());
        assert_eq!(stats.fenced, 0, "a heartbeat-renewed lease was revoked");
    }
    assert_eq!(
        w.handle_polls() - polls0,
        0,
        "idle ready rounds polled handles despite leases"
    );
    assert!(w.take_expired().is_empty());
    // One release still wakes exactly its waiter with O(1) polls.
    holder.release(&names[7]).unwrap();
    let polls1 = w.handle_polls();
    let mut got = Vec::new();
    while got.is_empty() {
        got = w.poll_ready();
    }
    assert_eq!(got, vec![names[7].clone()]);
    assert!(w.handle_polls() - polls1 <= 2, "release woke O(1) polls");
    w.release(&names[7]).unwrap();
    // Drain everything clean.
    for (i, n) in names.iter().enumerate() {
        if i != 7 {
            holder.release(n).unwrap();
        }
    }
    let mut done = 1usize;
    while done < names.len() {
        for n in w.poll_ready() {
            w.release(&n).unwrap();
            done += 1;
        }
    }
}

/// Seeded deterministic explorer runs over ready-mode sessions: the
/// sim world disables the fallback sweep, so armed names resolve
/// *only* through their tokens — every schedule's drain converging is
/// the no-lost-wakeup proof, and the per-lock oracles are the
/// mutual-exclusion check. (Formerly a hand-rolled random loop; a
/// failing seed now reproduces verbatim via `sim::run_one(&cfg, seed)`
/// and shrinks to a replayable artifact — see TESTING.md.)
#[test]
fn prop_explored_schedules_complete_on_wakeups_alone() {
    for seed in 0..12u64 {
        let cfg = SimConfig {
            procs: 2 + (seed % 3) as u32,
            locks: 1 + (seed % 5) as u32,
            nodes: 2 + (seed % 2) as u16,
            budget: 1 + (seed % 4),
            lease_ticks: 32,
            ring_capacity: 16,
            max_steps: 400,
            drain_rounds: 3_000,
            crash_prob: 0.0,
            zombie_prob: 0.0,
            max_crashes: 0,
            // Arms are their own scheduled steps on odd seeds, so the
            // arm-vs-handoff window is explored explicitly; even seeds
            // keep the production auto-arm path.
            manual_arm: seed % 2 == 1,
            executor_steps: false,
            race_detect: false,
            shared: false,
            mode: SchedMode::Uniform,
        };
        let out = run_one(&cfg, seed);
        assert!(
            out.violation.is_none(),
            "seed {seed}: {:?} (lost wakeup or double grant)",
            out.violation
        );
        assert!(out.completed > 0, "seed {seed}: schedule was inert");
    }
}

/// The one threaded smoke test of this file: the ready-list scheduler
/// under real OS-thread multiplexing at small scale (the deterministic
/// coverage now lives in the explorer tests above).
#[test]
fn threaded_ready_mode_smoke() {
    let cluster = Cluster::new(2, 1 << 18, DomainConfig::counted());
    let svc = Arc::new(LockService::new(&cluster.domain, "qplock", 8));
    let procs = cluster.round_robin_procs(8);
    let wl = Workload::cycles(30).with_locks(16, 0.9).with_seed(0x3A11);
    let r = run_multiplexed_workload_mode(&svc, &procs, &wl, 2, PollMode::Ready);
    assert_eq!(r.violations, 0);
    assert_eq!(r.total_acquisitions(), 8 * 30);
    assert_eq!(r.local_class_remote_verbs(), 0);
}
