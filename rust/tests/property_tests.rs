//! Randomized property tests (proptest is not in the vendored
//! registry; generators run on the repo's own deterministic PRNG, with
//! every failure reproducible from the printed seed).
//!
//! Invariants covered: coordinator routing/placement, lock-protected
//! state under randomized schedules for random topologies, histogram
//! quantile bounds, Jain index bounds, address packing, and the model
//! checker's qplock battery over randomized (n, B) configurations.

use std::sync::Arc;

use qplock::coordinator::{
    run_multi_lock_workload, run_workload, Cluster, CsWork, LockService, Workload,
};
use qplock::locks::make_lock;
use qplock::rdma::{Addr, DomainConfig};
use qplock::stats::{jain_index, Histogram};
use qplock::util::prng::Prng;

const CASES: u64 = 24;

fn seeds() -> impl Iterator<Item = u64> {
    (0..CASES).map(|i| 0xC0FFEE ^ (i * 0x9E3779B9))
}

#[test]
fn prop_addr_pack_roundtrip() {
    for seed in seeds() {
        let mut rng = Prng::seed_from(seed);
        for _ in 0..500 {
            let node = rng.below(u16::MAX as u64 + 1) as u16;
            let word = rng.below(u32::MAX as u64 + 1) as u32;
            let a = Addr::new(node, word);
            assert_eq!(a.node(), node, "seed {seed}");
            assert_eq!(a.word(), word, "seed {seed}");
            assert_eq!(Addr::from_bits(a.to_bits()), a, "seed {seed}");
            assert_eq!(a.is_null(), node == 0 && word == 0, "seed {seed}");
        }
    }
}

#[test]
fn prop_histogram_quantiles_bounded_by_min_max() {
    for seed in seeds() {
        let mut rng = Prng::seed_from(seed);
        let mut h = Histogram::new();
        let mut min = u64::MAX;
        let mut max = 0u64;
        let n = 1 + rng.below(2_000);
        for _ in 0..n {
            let shift = rng.range(1, 40);
            let v = rng.below(1 << shift);
            h.record(v);
            min = min.min(v);
            max = max.max(v);
        }
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            let x = h.quantile(q);
            assert!(x >= min && x <= max, "seed {seed} q={q}: {x} ∉ [{min},{max}]");
        }
        assert_eq!(h.count(), n, "seed {seed}");
    }
}

#[test]
fn prop_histogram_quantile_monotone_in_q() {
    for seed in seeds() {
        let mut rng = Prng::seed_from(seed);
        let mut h = Histogram::new();
        for _ in 0..1_000 {
            h.record(rng.below(1_000_000));
        }
        let mut prev = 0;
        for i in 0..=20 {
            let x = h.quantile(i as f64 / 20.0);
            assert!(x >= prev, "seed {seed}: quantile not monotone");
            prev = x;
        }
    }
}

#[test]
fn prop_jain_bounds_and_scale_invariance() {
    for seed in seeds() {
        let mut rng = Prng::seed_from(seed);
        let n = 2 + rng.below(16) as usize;
        let xs: Vec<u64> = (0..n).map(|_| rng.below(1_000)).collect();
        let j = jain_index(&xs);
        assert!(
            (1.0 / n as f64 - 1e-9..=1.0 + 1e-9).contains(&j),
            "seed {seed}: jain {j} out of [1/{n}, 1]"
        );
        // Scale invariance.
        let xs3: Vec<u64> = xs.iter().map(|x| x * 3).collect();
        let j3 = jain_index(&xs3);
        assert!((j - j3).abs() < 1e-9, "seed {seed}: {j} vs {j3}");
    }
}

#[test]
fn prop_random_topologies_protect_shared_state() {
    // Random node counts, placements, algorithms, iteration counts: the
    // lock-protected non-atomic RMW on a shared cell must never lose an
    // update, and per-class op discipline must hold for qplock.
    let algos = ["qplock", "rdma-mcs", "spin-rcas", "cohort-tas"];
    for seed in seeds().take(10) {
        let mut rng = Prng::seed_from(seed);
        let nodes = 2 + rng.below(3) as u16;
        let nprocs = 2 + rng.below(5) as u32;
        let nlocal = rng.below(nprocs as u64 + 1) as u32;
        let algo = *rng.pick(&algos);
        let iters = 50 + rng.below(150);
        let budget = 1 + rng.below(16);

        let c = Cluster::new(nodes, 1 << 18, DomainConfig::counted());
        let lock = make_lock(algo, &c.domain, 0, nprocs, budget);
        let procs = c.spread_procs(nprocs, nlocal, 0);

        // Shared cell + non-atomic RMW in the CS.
        let cell = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let cell2 = Arc::clone(&cell);
        let wl = Workload::cycles(iters)
            .with_seed(seed)
            .with_cs(CsWork::Callback(Arc::new(move |_pid| {
                let v = cell2.load(std::sync::atomic::Ordering::Relaxed);
                std::hint::spin_loop();
                cell2.store(v + 1, std::sync::atomic::Ordering::Relaxed);
            })));
        let r = run_workload(&c.domain, &lock, &procs, &wl);
        assert_eq!(r.violations, 0, "seed {seed} algo {algo}");
        assert_eq!(
            cell.load(std::sync::atomic::Ordering::Relaxed),
            nprocs as u64 * iters,
            "seed {seed} algo {algo}: lost updates"
        );
        if algo == "qplock" {
            for p in &r.procs {
                if p.class == qplock::locks::Class::Local {
                    assert_eq!(p.ops.remote_total(), 0, "seed {seed}");
                }
            }
        }
    }
}

#[test]
fn prop_service_end_to_end_op_asymmetry() {
    // The paper's headline claim, end to end through the *service*
    // (hash-routed placement, pid assignment, handle-cache sessions),
    // over randomized topologies and lock names:
    //  * a local-class qplock handle completes full lock/unlock cycles
    //    with exactly ZERO remote verbs (and zero loopback) in its
    //    ProcMetrics;
    //  * an uncontended remote-class handle stays O(1): per acquisition
    //    exactly 1 rCAS + 1 rWrite + 1 rRead, per release 1 rCAS —
    //    independent of topology, name, or how many cycles ran.
    for seed in seeds().take(8) {
        let mut rng = Prng::seed_from(seed);
        let nodes = 2 + rng.below(3) as u16;
        let cycles = 20 + rng.below(200);
        let name = format!("prop-lk-{}", rng.next_u64());

        let c = Cluster::new(nodes, 1 << 16, DomainConfig::counted());
        let svc = Arc::new(LockService::new(&c.domain, "qplock", 8));
        let home = svc.route(&name);

        // Local-class session: lives on the lock's home node.
        let mut local_sess = svc.session(home);
        for _ in 0..cycles {
            local_sess.with_lock(&name, || {}).unwrap();
        }
        let ls = local_sess.local_class_metrics().snapshot();
        let lr = local_sess.remote_class_metrics().snapshot();
        assert_eq!(
            ls.remote_total(),
            0,
            "seed {seed}: local class must never touch the NIC"
        );
        assert_eq!(ls.loopback, 0, "seed {seed}");
        assert!(ls.local_total() > 0, "seed {seed}: cycles really ran");
        assert_eq!(lr.remote_total(), 0, "seed {seed}: no remote handles minted");

        // Remote-class session on some other node, uncontended.
        let away = (home + 1) % nodes;
        let mut remote_sess = svc.session(away);
        for _ in 0..cycles {
            remote_sess.with_lock(&name, || {}).unwrap();
        }
        let rs = remote_sess.remote_class_metrics().snapshot();
        assert_eq!(rs.remote_cas, 2 * cycles, "seed {seed}: rCAS acquire+release");
        assert_eq!(rs.remote_write, cycles, "seed {seed}: Peterson victim write");
        assert_eq!(rs.remote_read, cycles, "seed {seed}: one other-tail check");
        assert_eq!(rs.loopback, 0, "seed {seed}");
    }
}

#[test]
fn prop_multi_lock_table_random_configs() {
    // Random table sizes, skews, and topologies through the sharded
    // service: totals must be exact, mutual exclusion per named lock
    // must hold, and local-class handles must stay off the NIC.
    for seed in seeds().take(6) {
        let mut rng = Prng::seed_from(seed);
        let nodes = 2 + rng.below(3) as u16;
        let nprocs = 2 + rng.below(5) as u32;
        let nlocks = 1 + rng.below(512) as u32;
        let skew = [0.0, 0.6, 0.99, 1.2][rng.below(4) as usize];
        let iters = 40 + rng.below(120);

        let c = Cluster::new(nodes, 1 << 19, DomainConfig::counted());
        let svc = Arc::new(LockService::new(&c.domain, "qplock", 8));
        let procs = c.round_robin_procs(nprocs);
        let wl = Workload::cycles(iters)
            .with_seed(seed)
            .with_locks(nlocks, skew);
        let r = run_multi_lock_workload(&svc, &procs, &wl);
        assert_eq!(r.violations, 0, "seed {seed}");
        assert_eq!(
            r.total_acquisitions(),
            nprocs as u64 * iters,
            "seed {seed}"
        );
        assert_eq!(
            r.per_lock_entries.iter().sum::<u64>(),
            nprocs as u64 * iters,
            "seed {seed}: every CS entry attributed to exactly one lock"
        );
        assert_eq!(svc.len(), nlocks as usize, "seed {seed}");
        assert_eq!(r.local_class_remote_verbs(), 0, "seed {seed}");
        for p in &r.procs {
            assert!(p.distinct_locks <= nlocks as u64, "seed {seed}");
            assert_eq!(p.cache_misses, p.distinct_locks, "seed {seed}");
        }
    }
}

#[test]
fn prop_qplock_spec_battery_random_configs() {
    // Random (n, B) within tractable bounds: the paper's properties must
    // hold for every configuration, not just the hand-picked ones.
    for seed in seeds().take(6) {
        let mut rng = Prng::seed_from(seed);
        let n = 2 + rng.below(2) as usize; // 2..=3
        let b = 1 + rng.below(3) as u8; // 1..=3
        let r = qplock::mc::check_all(
            &qplock::mc::models::qplock_spec::QpSpec::new(n, b),
            1 << 22,
        );
        assert!(!r.truncated, "seed {seed} n={n} B={b}");
        assert!(
            r.mutual_exclusion.holds()
                && r.deadlock_free.holds()
                && r.starvation_free.holds()
                && r.dead_and_livelock_free.holds(),
            "seed {seed} n={n} B={b}"
        );
    }
}

#[test]
fn prop_spread_procs_always_well_formed() {
    for seed in seeds() {
        let mut rng = Prng::seed_from(seed);
        let nodes = 1 + rng.below(5) as u16;
        let c = Cluster::new(nodes, 1 << 10, DomainConfig::counted());
        let n = 1 + rng.below(20) as u32;
        let nlocal = rng.below(n as u64 + 1) as u32;
        let procs = c.spread_procs(n, nlocal, 0);
        assert_eq!(procs.len(), n as usize, "seed {seed}");
        assert!(procs.iter().all(|p| p.node < nodes), "seed {seed}");
        let locals = procs.iter().filter(|p| p.node == 0).count() as u32;
        if nodes > 1 {
            assert_eq!(locals, nlocal, "seed {seed}");
        }
        // pids unique and dense.
        let mut pids: Vec<u32> = procs.iter().map(|p| p.pid).collect();
        pids.sort_unstable();
        assert_eq!(pids, (0..n).collect::<Vec<_>>(), "seed {seed}");
    }
}

/// One deterministic randomized qplock schedule (polls, unlocks, arms,
/// ring drains, lease ticks, sweeps) with per-actor verb accounting.
/// Returns every actor's op-count snapshot (handles first, then the
/// per-node sweeper endpoints) with `net_ns` zeroed — batching changes
/// *pricing*, never the verb stream, so everything else must match.
fn scheduled_verb_totals(seed: u64, batching: bool) -> Vec<qplock::rdma::ProcMetricsSnapshot> {
    use qplock::locks::{AsyncLockHandle, LockHandle, SweepStats, WakeupReg};
    use qplock::rdma::{Endpoint, RdmaDomain, WakeupRing};

    let mut rng = Prng::seed_from(seed);
    let nodes = (1 + rng.below(2)) as u16;
    let home = rng.below(nodes as u64) as u16;
    let budget = 1 + rng.below(4);
    let n = (2 + rng.below(3)) as usize;
    let places: Vec<u16> = (0..n).map(|_| rng.below(nodes as u64) as u16).collect();

    let domain = RdmaDomain::new(nodes, 1 << 14, DomainConfig::counted().with_batching(batching));
    let lock = qplock::locks::make_lock("qplock", &domain, home, n as u32, budget);
    assert!(lock.enable_leases(10));
    let sweep_eps: Vec<Endpoint> = (0..nodes).map(|nd| domain.endpoint(nd)).collect();
    let mut metrics = Vec::new();
    let mut handles: Vec<Box<dyn LockHandle>> = (0..n)
        .map(|i| {
            let ep = domain.endpoint(places[i]);
            metrics.push(Arc::clone(&ep.metrics));
            lock.handle(ep, i as u32)
        })
        .collect();
    let mut rings: Vec<WakeupRing> = (0..n)
        .map(|i| WakeupRing::new(domain.endpoint(places[i]), 8))
        .collect();
    let mut sweep = SweepStats::default();

    for _ in 0..400 {
        let r = rng.below(100);
        if r < 12 {
            domain.advance_lease_clock(1 + rng.below(3));
            continue;
        }
        if r < 20 {
            // Sweep pass from every node: exercises the batched
            // per-pass repair path in `QpInner::sweep_node`.
            let now = domain.lease_now();
            for ep in &sweep_eps {
                lock.sweep_leases(ep, now, &mut sweep);
            }
            continue;
        }
        let h = rng.below(n as u64) as usize;
        let a = handles[h].as_async().expect("qplock is poll-capable");
        match rng.below(8) {
            0..=4 => {
                let _ = a.poll_lock();
            }
            5 => {
                if a.is_held() {
                    // Held releases hit the batched `q_unlock` scope,
                    // signalled or tail-reset as the schedule dictates.
                    let _ = handles[h].try_unlock();
                }
            }
            6 => {
                let reg = WakeupReg {
                    ring: rings[h].header(),
                    token: h as u64,
                    ring_slots: rings[h].lane_slots(),
                };
                let _ = a.arm_wakeup(reg);
            }
            _ => while rings[h].pop().is_some() {},
        }
    }

    metrics
        .iter()
        .chain(sweep_eps.iter().map(|ep| &ep.metrics))
        .map(|m| {
            let mut s = m.snapshot();
            s.net_ns = 0;
            s
        })
        .collect()
}

#[test]
fn prop_doorbell_batching_is_protocol_equivalent() {
    // ISSUE satellite: the batched release / sweep-repair / heartbeat
    // paths must be protocol-equivalent to unbatched issue — identical
    // per-class verb totals for every actor on every seed. Runs under
    // the debug-build verb sanitizer, so any contract violation on the
    // batched path panics here too.
    for seed in seeds() {
        let unbatched = scheduled_verb_totals(seed, false);
        let batched = scheduled_verb_totals(seed, true);
        assert_eq!(unbatched, batched, "seed {seed}: verb totals diverged");
    }
}
