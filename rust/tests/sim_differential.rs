//! Differential validation: the real Rust stack and the Python oracle
//! (`python/tools/poll_model_check.py --trace`) replay identical seeds
//! through the lockstep handle-level schedule and must emit
//! byte-identical JSONL traces. Any divergence between
//! `locks/qplock.rs` and its transliteration is a test failure here —
//! a line-level diff, not a latent blind spot.
//!
//! Skips (with a notice) when no `python3` is on PATH; CI always runs
//! it, both here and as a standalone `diff` step.

use std::path::Path;
use std::process::Command;

use qplock::sim::differential::differential_trace;

fn python_oracle(seed: u64, steps: u32) -> Option<Vec<String>> {
    let script = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .join("python/tools/poll_model_check.py");
    let out = Command::new("python3")
        .arg(&script)
        .args(["--trace", "-"])
        .args(["--seed", &seed.to_string()])
        .args(["--steps", &steps.to_string()])
        .output()
        .ok()?;
    if !out.status.success() {
        panic!(
            "python oracle failed (seed {seed}): {}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
    Some(
        String::from_utf8(out.stdout)
            .expect("utf-8 trace")
            .lines()
            .map(|l| l.to_string())
            .collect(),
    )
}

#[test]
fn rust_and_python_traces_match_on_shared_seeds() {
    if Command::new("python3").arg("--version").output().is_err() {
        eprintln!("skipping: python3 not on PATH (CI runs this via the differential step)");
        return;
    }
    let steps = 400u32;
    for seed in [0u64, 1, 2, 3, 4, 5, 6, 7] {
        let rust = differential_trace(seed, steps);
        let python = python_oracle(seed, steps).expect("python3 ran a moment ago");
        assert_eq!(
            rust.len(),
            python.len(),
            "seed {seed}: trace lengths differ ({} vs {})",
            rust.len(),
            python.len()
        );
        for (i, (r, p)) in rust.iter().zip(python.iter()).enumerate() {
            assert_eq!(
                r, p,
                "seed {seed}: first divergence at line {i}:\n  rust:   {r}\n  python: {p}"
            );
        }
    }
}

#[test]
fn differential_schedule_reaches_the_protocol_depths() {
    // The lockstep alphabet must not silently degenerate: across the
    // shared seeds it has to produce held cycles, armed registrations
    // with published tokens, fences with repairs, and fenced late
    // writes ("expired" unlock outcomes) — otherwise a trace match
    // proves nothing.
    let mut outcomes = std::collections::HashSet::new();
    for seed in 0..24u64 {
        for line in differential_trace(seed, 400) {
            for key in [
                "\"out\":\"held\"",
                "\"out\":\"armed\"",
                "\"out\":\"expired\"",
                "\"out\":\"stalled\"",
                "\"out\":\"woken\"",
            ] {
                if line.contains(key) {
                    outcomes.insert(key);
                }
            }
            if line.contains("\"op\":\"drain\"") && !line.contains("[]") {
                outcomes.insert("token-consumed");
            }
            if line.contains("\"op\":\"sweep\"") && !line.contains("\"relayed\":0") {
                outcomes.insert("relay");
            }
            if line.contains("\"op\":\"sweep\"") && !line.contains("\"fenced\":0") {
                outcomes.insert("fence");
            }
        }
    }
    for key in [
        "\"out\":\"held\"",
        "\"out\":\"armed\"",
        "\"out\":\"expired\"",
        "\"out\":\"stalled\"",
        "\"out\":\"woken\"",
        "token-consumed",
        "relay",
        "fence",
    ] {
        assert!(outcomes.contains(key), "never observed {key}");
    }
}
