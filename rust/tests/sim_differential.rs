//! Differential validation: the real Rust stack and the Python oracle
//! (`python/tools/poll_model_check.py --trace`) replay identical seeds
//! through the lockstep handle-level schedule and must emit
//! byte-identical JSONL traces. Any divergence between
//! `locks/qplock.rs` and its transliteration is a test failure here —
//! a line-level diff, not a latent blind spot.
//!
//! Skips (with a notice) when no `python3` is on PATH; CI always runs
//! it, both here and as a standalone `diff` step.

use std::path::Path;
use std::process::Command;

use qplock::sim::differential::differential_trace;

fn python_oracle(seed: u64, steps: u32) -> Option<Vec<String>> {
    let script = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .join("python/tools/poll_model_check.py");
    let out = Command::new("python3")
        .arg(&script)
        .args(["--trace", "-"])
        .args(["--seed", &seed.to_string()])
        .args(["--steps", &steps.to_string()])
        .output()
        .ok()?;
    if !out.status.success() {
        panic!(
            "python oracle failed (seed {seed}): {}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
    Some(
        String::from_utf8(out.stdout)
            .expect("utf-8 trace")
            .lines()
            .map(|l| l.to_string())
            .collect(),
    )
}

#[test]
fn rust_and_python_traces_match_on_shared_seeds() {
    if Command::new("python3").arg("--version").output().is_err() {
        eprintln!("skipping: python3 not on PATH (CI runs this via the differential step)");
        return;
    }
    let steps = 400u32;
    for seed in [0u64, 1, 2, 3, 4, 5, 6, 7] {
        let rust = differential_trace(seed, steps);
        let python = python_oracle(seed, steps).expect("python3 ran a moment ago");
        assert_eq!(
            rust.len(),
            python.len(),
            "seed {seed}: trace lengths differ ({} vs {})",
            rust.len(),
            python.len()
        );
        for (i, (r, p)) in rust.iter().zip(python.iter()).enumerate() {
            assert_eq!(
                r, p,
                "seed {seed}: first divergence at line {i}:\n  rust:   {r}\n  python: {p}"
            );
        }
    }
}

/// Pull the `"modes":[...]` array out of a trace header line.
fn header_modes(header: &str) -> Vec<u32> {
    let start = header.find("\"modes\":[").expect("header carries modes") + "\"modes\":[".len();
    let end = start + header[start..].find(']').expect("modes array closes");
    header[start..end]
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.parse().expect("mode digit"))
        .collect()
}

/// Pull an integer field (`"key":N`) out of a trace line.
fn field_u32(line: &str, key: &str) -> Option<u32> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let digits: String = line[start..].chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

#[test]
fn differential_schedule_reaches_the_protocol_depths() {
    // The lockstep alphabet must not silently degenerate: across the
    // shared seeds it has to produce held cycles, armed registrations
    // with published tokens, fences with repairs, fenced late writes
    // ("expired" unlock outcomes), and — since ISSUE 10 widened the
    // alphabet with reader handles — shared holds, exclusive holds, and
    // genuinely overlapping readers. Otherwise a trace match proves
    // nothing.
    let mut outcomes = std::collections::HashSet::new();
    for seed in 0..24u64 {
        let trace = differential_trace(seed, 400);
        let modes = header_modes(&trace[0]);
        if modes.contains(&1) {
            outcomes.insert("reader-drawn");
        }
        let mut held = vec![false; modes.len()];
        for line in &trace {
            for key in [
                "\"out\":\"held\"",
                "\"out\":\"armed\"",
                "\"out\":\"expired\"",
                "\"out\":\"stalled\"",
                "\"out\":\"woken\"",
            ] {
                if line.contains(key) {
                    outcomes.insert(key);
                }
            }
            if line.contains("\"op\":\"drain\"") && !line.contains("[]") {
                outcomes.insert("token-consumed");
            }
            if line.contains("\"op\":\"sweep\"") && !line.contains("\"relayed\":0") {
                outcomes.insert("relay");
            }
            if line.contains("\"op\":\"sweep\"") && !line.contains("\"fenced\":0") {
                outcomes.insert("fence");
            }
            // Per-mode hold coverage, reconstructed from the trace the
            // way the oracle diff sees it (crash/lease races can leave
            // this approximate; it only feeds coverage, not an ME
            // check — the ME oracle lives in the sim explorer).
            if line.contains("\"op\":\"poll\"") && line.contains("\"out\":\"held\"") {
                let h = field_u32(line, "h").expect("poll carries h") as usize;
                held[h] = true;
                outcomes.insert(if modes[h] == 1 { "reader-held" } else { "writer-held" });
                if (0..modes.len()).filter(|&j| held[j] && modes[j] == 1).count() >= 2 {
                    outcomes.insert("reader-overlap");
                }
            }
            if line.contains("\"op\":\"unlock\"") && !line.contains("\"out\":\"noop\"") {
                let h = field_u32(line, "h").expect("unlock carries h") as usize;
                held[h] = false;
            }
        }
    }
    for key in [
        "\"out\":\"held\"",
        "\"out\":\"armed\"",
        "\"out\":\"expired\"",
        "\"out\":\"stalled\"",
        "\"out\":\"woken\"",
        "token-consumed",
        "relay",
        "fence",
        "reader-drawn",
        "reader-held",
        "writer-held",
        "reader-overlap",
    ] {
        assert!(outcomes.contains(key), "never observed {key}");
    }
}
