//! Schedule-explorer acceptance: ≥ 500 seeded deterministic schedules
//! — uniform, PCT, manual-arm, and crash-injecting — drive the real
//! stack through the `sim` world and pass the mutual-exclusion,
//! progress, and lease-repair oracles; recorded schedules replay
//! deterministically; crashed clients' pid slots all return to their
//! pools (the ROADMAP reclamation item, observed at quiescence).
//!
//! Every failure message carries the seed, and a failing schedule can
//! be re-run verbatim: `sim::run_one(&cfg, seed)` (or shrunk +
//! replayed through `qplock sim --replay`).

use qplock::sim::{self, run_one, SchedMode, SimConfig, TraceFile};

fn crashy(mode: SchedMode, manual_arm: bool) -> SimConfig {
    SimConfig {
        procs: 4,
        locks: 3,
        nodes: 2,
        budget: 4,
        lease_ticks: 32,
        ring_capacity: 8,
        max_steps: 300,
        drain_rounds: 4_000,
        crash_prob: 0.05,
        zombie_prob: 0.5,
        max_crashes: 2,
        manual_arm,
        executor_steps: false,
        race_detect: false,
        shared: false,
        mode,
    }
}

#[test]
fn acceptance_500_defended_schedules_pass_all_oracles() {
    // 4 configurations x 125 seeds = 500 schedules, crash injection
    // on throughout. With every defense in place (no mutation knob),
    // every schedule must pass: no ME violation, every drain
    // converges, every fence reaps, and every crashed pid slot is
    // reclaimed.
    let configs = [
        ("uniform", crashy(SchedMode::Uniform, false)),
        ("uniform+manual-arm", crashy(SchedMode::Uniform, true)),
        ("pct", crashy(SchedMode::Pct { depth: 3 }, false)),
        ("churn", crashy(SchedMode::Churn, true)),
    ];
    let mut crashes = 0u64;
    let mut completed = 0u64;
    let mut late_rejected = 0u64;
    let mut fenced = 0u64;
    for (label, cfg) in &configs {
        for seed in 0..125u64 {
            let out = run_one(cfg, seed);
            assert!(
                out.violation.is_none(),
                "{label} seed {seed}: {:?}",
                out.violation
            );
            assert_eq!(
                out.sweep.fenced, out.sweep.reaped,
                "{label} seed {seed}: repairs left dangling"
            );
            assert_eq!(
                out.orphaned_left, 0,
                "{label} seed {seed}: crashed pid slots never reclaimed"
            );
            crashes += out.crashes as u64;
            completed += out.completed;
            late_rejected += out.late_rejected;
            fenced += out.sweep.fenced;
        }
    }
    // The sweep exercised what it claims to: crashes were injected,
    // leases fenced and repaired, zombie late writes rejected, and
    // plenty of clean cycles completed around them.
    assert!(completed > 1_000, "schedules were inert: {completed}");
    assert!(crashes > 100, "crash injection never fired: {crashes}");
    assert!(fenced > 50, "no lease was ever fenced: {fenced}");
    assert!(late_rejected > 0, "no zombie late write was ever fenced");
}

#[test]
fn schedules_are_deterministic_and_replayable() {
    let cfg = crashy(SchedMode::Uniform, false);
    for seed in [3u64, 17, 99] {
        let a = run_one(&cfg, seed);
        let b = run_one(&cfg, seed);
        assert_eq!(a.steps, b.steps, "seed {seed}: schedule not reproducible");
        assert_eq!(a.violation, b.violation, "seed {seed}");
        assert_eq!(a.completed, b.completed, "seed {seed}");
        assert_eq!(a.crashes, b.crashes, "seed {seed}");
        // Replaying the recorded steps reproduces the run exactly.
        let r = sim::replay(&cfg, &a.steps);
        assert_eq!(r.violation, a.violation, "seed {seed}: replay diverged");
        assert_eq!(r.completed, a.completed, "seed {seed}: replay diverged");
        assert_eq!(r.crashes, a.crashes, "seed {seed}: replay diverged");
    }
}

#[test]
fn traces_round_trip_through_the_artifact_format() {
    let cfg = crashy(SchedMode::Pct { depth: 2 }, true);
    let out = run_one(&cfg, 41);
    let tf = TraceFile {
        config: cfg.clone(),
        seed: 41,
        violation: out.violation.as_ref().map(|v| v.kind().to_string()),
        steps: out.steps.clone(),
    };
    let back = TraceFile::decode(&tf.encode()).expect("own format parses");
    assert_eq!(back.steps, out.steps);
    let r = sim::replay(&back.config, &back.steps);
    assert_eq!(r.violation, out.violation);
    assert_eq!(r.completed, out.completed);
}

#[test]
fn executor_step_schedules_pass_all_oracles_and_cover_the_new_alphabet() {
    // PR 7: the executor-shaped steps — single-token steals, session
    // migration, waker drops, spurious polls of armed names — are
    // scheduled alongside crashes, and every schedule still passes the
    // ME/progress/lease oracles: a dropped waker falls back to the
    // scan set and re-arms, a spurious resolution leaves only a
    // discardable dirty token, and a thief's partial ring consumption
    // never strands the rest of the batch.
    let cfg = SimConfig {
        executor_steps: true,
        ..crashy(SchedMode::Uniform, false)
    };
    let (mut steals, mut migrates, mut drops, mut spurious) = (0u64, 0u64, 0u64, 0u64);
    for seed in 0..100u64 {
        let out = run_one(&cfg, seed);
        assert!(
            out.violation.is_none(),
            "seed {seed}: {:?}",
            out.violation
        );
        assert_eq!(
            out.sweep.fenced, out.sweep.reaped,
            "seed {seed}: repairs left dangling"
        );
        for s in &out.steps {
            match s {
                sim::Step::Steal { .. } => steals += 1,
                sim::Step::Migrate { .. } => migrates += 1,
                sim::Step::WakerDrop { .. } => drops += 1,
                sim::Step::SpuriousWake { .. } => spurious += 1,
                _ => {}
            }
        }
    }
    assert!(steals > 0, "no steal was ever scheduled");
    assert!(migrates > 0, "no migration was ever scheduled");
    assert!(drops > 0, "no waker drop was ever scheduled");
    assert!(spurious > 0, "no spurious wake was ever scheduled");

    // Schedules containing the new ops replay deterministically and
    // round-trip through the artifact format.
    let a = run_one(&cfg, 7);
    let r = sim::replay(&cfg, &a.steps);
    assert_eq!(r.violation, a.violation, "replay diverged");
    assert_eq!(r.completed, a.completed, "replay diverged");
    let tf = TraceFile {
        config: cfg.clone(),
        seed: 7,
        violation: None,
        steps: a.steps.clone(),
    };
    let back = TraceFile::decode(&tf.encode()).expect("own format parses");
    assert!(back.config.executor_steps, "flag lost in the round trip");
    assert_eq!(back.steps, a.steps, "new ops lost in the round trip");
}

#[test]
fn shared_mode_schedules_pass_the_per_mode_oracles() {
    // ISSUE 10: reader crowds, batch closes, generation drains, and
    // crash injection (kills and zombies) all interleave, and every
    // schedule passes the per-mode oracles — readers never overlap a
    // writer, writers overlap nothing — plus progress and lease
    // repair. `crashy` keeps its crash probability, so crashed shared
    // holders exercise the sweeper's proxy-decrement repair.
    let cfg = SimConfig {
        shared: true,
        ..crashy(SchedMode::Uniform, false)
    };
    let mut shared_submits = 0u64;
    for seed in 0..40u64 {
        let out = run_one(&cfg, seed);
        assert!(out.violation.is_none(), "seed {seed}: {:?}", out.violation);
        assert_eq!(
            out.sweep.fenced, out.sweep.reaped,
            "seed {seed}: repairs left dangling"
        );
        shared_submits += out
            .steps
            .iter()
            .filter(|s| matches!(s, sim::Step::SubmitShared { .. }))
            .count() as u64;
    }
    assert!(shared_submits > 0, "no shared submit was ever scheduled");

    // Shared schedules replay deterministically and round-trip through
    // the artifact format with the mode flag intact.
    let a = run_one(&cfg, 3);
    let r = sim::replay(&cfg, &a.steps);
    assert_eq!(r.violation, a.violation, "replay diverged");
    assert_eq!(r.completed, a.completed, "replay diverged");
    let tf = TraceFile {
        config: cfg.clone(),
        seed: 3,
        violation: None,
        steps: a.steps.clone(),
    };
    let back = TraceFile::decode(&tf.encode()).expect("own format parses");
    assert!(back.config.shared, "flag lost in the round trip");
    assert_eq!(back.steps, a.steps, "shared ops lost in the round trip");
}

#[test]
fn local_class_schedules_issue_zero_remote_verbs() {
    // The paper's headline under arbitrary explored interleavings: a
    // one-node world makes every handle local-class, and no schedule
    // (submits, polls, arms, ready rounds, cancels, releases, sweeps)
    // may touch the NIC.
    let cfg = SimConfig {
        nodes: 1,
        crash_prob: 0.0,
        ..crashy(SchedMode::Uniform, false)
    };
    for seed in 0..16u64 {
        let out = run_one(&cfg, seed);
        assert!(out.violation.is_none(), "seed {seed}: {:?}", out.violation);
        assert_eq!(
            out.local_remote_verbs, 0,
            "seed {seed}: local class used the NIC"
        );
    }
}
