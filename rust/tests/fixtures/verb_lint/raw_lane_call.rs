// Seeded violation: an explicit lane-dispatched CAS on a cohort tail,
// bypassing the contract accessors. verb-lint must flag line 6.
use qplock::rdma::{Addr, Endpoint, RmwLane};

pub fn sneaky_relay(ep: &Endpoint, tail: Addr) -> u64 {
    ep.cas_lane(tail, 0, 1, RmwLane::Cpu)
}
