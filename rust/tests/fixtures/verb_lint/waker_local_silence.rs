// Seeded violation: the Peterson waker block lives in home-node
// registers and the registry marks both its words NIC-silent — a
// signaller co-located with the block must read it with CPU ops,
// never the NIC loopback. verb-lint must flag line 9.
use qplock::rdma::contract::WAKER_RING;
use qplock::rdma::{Addr, Endpoint};

pub fn sneaky_signal(ep: &Endpoint, block: Addr) -> u64 {
    ep.r_read(block.offset(WAKER_RING))
}
