// Seeded violation: a descriptor word that exists nowhere in the
// word-ownership registry. verb-lint must flag the declaration line.
use qplock::rdma::{Addr, Endpoint};

const DESC_SPARE: u32 = 7;

pub fn scribble(ep: &Endpoint, desc: Addr) {
    ep.write(desc.offset(DESC_SPARE), 1);
}
