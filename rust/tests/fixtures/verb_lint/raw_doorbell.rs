// Seeded violation: two raw verb issues in one function with no
// DoorbellBatch scope — each rings its own doorbell where a chained
// post would ring one. verb-lint must flag line 8 (the second issue).
use qplock::rdma::{Addr, Endpoint};

pub fn double_ring(ep: &Endpoint, desc: Addr, ring: Addr) {
    let token = ep.r_read(desc);
    ep.r_write(ring, token + 1);
}
