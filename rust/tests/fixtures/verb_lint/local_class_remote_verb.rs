// Seeded violation: a local-class code path reaching for the NIC —
// the headline invariant says local-class processes issue zero remote
// verbs, loopback included. verb-lint must flag line 10.
use qplock::locks::Class;
use qplock::rdma::contract::DESC_BUDGET;
use qplock::rdma::{Addr, Endpoint};

pub fn probe(ep: &Endpoint, desc: Addr, cls: Class) -> u64 {
    match cls {
        Class::Local => ep.r_read(desc.offset(DESC_BUDGET)),
        Class::Remote => ep.r_read(desc.offset(DESC_BUDGET)),
    }
}
