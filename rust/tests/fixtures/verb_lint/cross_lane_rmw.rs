// Seeded violation: the lease word is CPU-owned; reaching it through
// the NIC lane is the Table-1 mixed-atomicity hazard. Flag line 7.
use qplock::rdma::contract::DESC_LEASE;
use qplock::rdma::{Addr, Endpoint, RmwLane};

pub fn fence_from_afar(ep: &Endpoint, desc: Addr) -> u64 {
    ep.cas_lane(desc.offset(DESC_LEASE), 0, 1, RmwLane::Nic)
}
