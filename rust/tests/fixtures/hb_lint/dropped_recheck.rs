//! Seeded `hb-lint` violation: the arm path publishes its token and
//! ring and opens the sticky gate, but the post-registration budget
//! re-check is gone — the `SKIP_ARM_RECHECK` hazard committed to
//! source. `hb-dropped-recheck` pins the gate-open line.

fn arm_wakeup(&mut self) -> ArmOutcome {
    contract::desc_write_sc(&self.ep, Role::Session, self.desc, Word::DescWakeToken, t);
    contract::desc_write_sc(&self.ep, Role::Session, self.desc, Word::DescWakeRing, r);
    self.shared.wakeups.store(true, SeqCst);
    ArmOutcome::Armed
}
