//! Seeded `hb-lint` violation: a new disarm path writes the
//! arm-budget-window gate word without joining the edge's declared
//! `gate_writers` set. `hb-unregistered-edge` pins the write's line.

fn rogue_disarm(&mut self) {
    contract::desc_write_sc(&self.ep, Role::Session, self.desc, Word::DescWakeRing, 0);
}
