//! Seeded `hb-lint` violation: the sticky gate flag's Dekker store is
//! downgraded from SeqCst — compiles clean, loses wakeups under
//! store-load reordering. `hb-relaxed-ordering` pins the downgraded
//! ordering token's line.

fn arm_wakeup(&mut self) -> ArmOutcome {
    contract::desc_write_sc(&self.ep, Role::Session, self.desc, Word::DescWakeToken, t);
    contract::desc_write_sc(&self.ep, Role::Session, self.desc, Word::DescWakeRing, r);
    self.shared.wakeups.store(true, Ordering::Relaxed);
    if contract::desc_read_sc(&self.ep, Role::Session, self.desc, Word::DescBudget) != WAITING {
        return ArmOutcome::AlreadyReady;
    }
    ArmOutcome::Armed
}
