//! Seeded `hb-lint` violation: ring registration before the token
//! write — a passer can read the ring, follow it, and publish a stale
//! token. `hb-order` pins the early ring write's line.

fn arm_wakeup(&mut self) -> ArmOutcome {
    contract::desc_write_sc(&self.ep, Role::Session, self.desc, Word::DescWakeRing, r);
    contract::desc_write_sc(&self.ep, Role::Session, self.desc, Word::DescWakeToken, t);
    self.shared.wakeups.store(true, SeqCst);
    if contract::desc_read_sc(&self.ep, Role::Session, self.desc, Word::DescBudget) != WAITING {
        return ArmOutcome::AlreadyReady;
    }
    ArmOutcome::Armed
}
