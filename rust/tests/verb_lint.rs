//! Integration proof of the verb-contract layer (TESTING.md Layer 4):
//! the shipped tree lints clean, each seeded violation fixture is
//! flagged at its exact `file:line`, and the dynamic NIC-level
//! sanitizer rediscovers the PR 3 mis-laned ring-cursor hazard when
//! its mutation tooth is enabled.
//!
//! The fixtures live under `tests/fixtures/verb_lint/` — a directory
//! cargo does not compile — so each one can contain exactly the code
//! the lint must reject.

use std::fs;
use std::path::PathBuf;

use qplock::analysis::{lint_source, lint_tree, Diagnostic, FileClass};

fn fixture(name: &str) -> String {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/verb_lint")
        .join(name);
    match fs::read_to_string(&p) {
        Ok(s) => s,
        Err(e) => panic!("{}: {e}", p.display()),
    }
}

fn lint_fixture(name: &str) -> Vec<Diagnostic> {
    // Fixtures model protocol-implementation code, so they get the
    // full rule set.
    lint_source(name, &fixture(name), FileClass::Protocol)
}

fn flagged(diags: &[Diagnostic], rule: &str, line: u32) -> bool {
    diags.iter().any(|d| d.rule == rule && d.line == line)
}

#[test]
fn clean_tree_lints_clean() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let diags = lint_tree(&src).expect("source tree must be readable");
    assert!(diags.is_empty(), "the tree must lint clean:\n{diags:#?}");
}

#[test]
fn raw_lane_call_fixture_is_flagged_at_line_6() {
    let d = lint_fixture("raw_lane_call.rs");
    assert!(flagged(&d, "raw-lane-call", 6), "{d:#?}");
}

#[test]
fn unregistered_word_fixture_is_flagged_at_line_5() {
    let d = lint_fixture("unregistered_word.rs");
    assert!(flagged(&d, "unregistered-offset", 5), "{d:#?}");
}

#[test]
fn cross_lane_rmw_fixture_is_flagged_at_line_7() {
    let d = lint_fixture("cross_lane_rmw.rs");
    assert!(flagged(&d, "lane-mismatch", 7), "{d:#?}");
}

#[test]
fn local_class_remote_verb_fixture_is_flagged_at_line_10() {
    let d = lint_fixture("local_class_remote_verb.rs");
    assert!(flagged(&d, "local-silence", 10), "{d:#?}");
}

#[test]
fn waker_block_remote_verb_fixture_is_flagged_at_line_9() {
    // PR 7: the Peterson-waker words are declared in the registry as
    // NIC-silent home-node registers, so the machine-checked contract
    // extends to the new protocol surface — a raw remote verb on the
    // waker ring word is rejected at its exact line.
    let d = lint_fixture("waker_local_silence.rs");
    assert!(flagged(&d, "local-silence", 9), "{d:#?}");
}

#[test]
fn raw_doorbell_fixture_is_flagged_at_line_8() {
    // PR 9: two raw verb issues in one function, no DoorbellBatch
    // scope — flagged at the second issue, where the extra doorbell
    // rings.
    let d = lint_fixture("raw_doorbell.rs");
    assert!(flagged(&d, "raw-doorbell", 8), "{d:#?}");
    // The fixture trips nothing else: reads and writes are not RMWs,
    // and no registry word is named.
    assert_eq!(d.len(), 1, "{d:#?}");
}

/// The dynamic half of the acceptance bar: with the seeded PR 3
/// hazard re-enabled (a co-located passer claiming the CPU-owned ring
/// cursor through the NIC lane), the NIC-level sanitizer must abort
/// the publish, naming the word and the illegal lane.
#[cfg(debug_assertions)]
#[test]
fn sanitizer_rediscovers_mislaned_ring_cursor() {
    use qplock::locks::qplock::QpLock;
    use qplock::locks::{AcqPhase, ArmOutcome, AsyncLockHandle, LockHandle, LockPoll, WakeupReg};
    use qplock::rdma::contract::test_knobs::MISLANE_RING_CURSOR;
    use qplock::rdma::{DomainConfig, RdmaDomain, WakeupRing};
    use std::sync::atomic::Ordering::SeqCst;

    let run = std::thread::spawn(|| {
        let d = RdmaDomain::new(1, 4096, DomainConfig::counted());
        let l = QpLock::create(&d, 0, 8);
        let mut holder = l.qp_handle(d.endpoint(0));
        let mut waiter = l.qp_handle(d.endpoint(0));
        let mut ring = WakeupRing::new(d.endpoint(0), 4);
        holder.lock();
        while waiter.phase() != AcqPhase::WaitBudget {
            assert_eq!(waiter.poll_lock(), LockPoll::Pending);
        }
        let reg = WakeupReg {
            ring: ring.header(),
            token: 9,
            ring_slots: ring.lane_slots(),
        };
        assert_eq!(waiter.arm_wakeup(reg), ArmOutcome::Armed);
        MISLANE_RING_CURSOR.store(true, SeqCst);
        // A local-class passer publishes through the CPU lane; the
        // tooth turns that claim into an rFAA — the exact mixed-lane
        // RMW the sanitizer exists to catch.
        holder.unlock();
        let _ = ring.pop(); // unreachable: the publish aborts
    });
    let err = run
        .join()
        .expect_err("the sanitizer must abort the mis-laned publish");
    MISLANE_RING_CURSOR.store(false, SeqCst);
    let msg = err
        .downcast::<String>()
        .expect("sanitizer aborts carry a String payload");
    assert!(msg.contains("ring-cpu-cursor"), "{msg}");
    assert!(msg.contains("NIC RMW"), "{msg}");
}
