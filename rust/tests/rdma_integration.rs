//! RDMA substrate integration: cross-thread visibility, the Table-1
//! semantics at the public API level, loopback/congestion accounting,
//! and timing-model ordering.

use std::time::Instant;

use qplock::rdma::{
    AtomicityMode, DomainConfig, LatencyModel, RdmaDomain, TimeMode,
};

#[test]
fn cross_node_visibility_is_immediate() {
    let d = RdmaDomain::new(4, 1 << 12, DomainConfig::counted());
    let home = d.endpoint(2);
    let a = home.alloc(1);
    for node in [0u16, 1, 3] {
        let ep = d.endpoint(node);
        ep.r_write(a, node as u64 + 100);
        assert_eq!(home.read(a), node as u64 + 100);
        assert_eq!(ep.r_read(a), node as u64 + 100);
    }
}

#[test]
fn concurrent_rcas_from_many_nodes_is_linearizable() {
    // N threads all rCAS(0 -> tag); exactly one may win.
    let d = RdmaDomain::new(4, 1 << 12, DomainConfig::counted());
    let home = d.endpoint(0);
    let a = home.alloc(1);
    for _trial in 0..50 {
        home.write(a, 0);
        let mut ts = vec![];
        for node in 0..4u16 {
            let ep = d.endpoint(node);
            ts.push(std::thread::spawn(move || ep.r_cas(a, 0, node as u64 + 1) == 0));
        }
        let winners: usize = ts.into_iter().map(|t| t.join().unwrap() as usize).sum();
        assert_eq!(winners, 1, "exactly one rCAS winner");
    }
}

#[test]
fn timed_mode_orders_local_loopback_remote() {
    // Wall-clock cost ordering must match the model: local ≪ loopback <
    // remote. Latencies far above per-op bookkeeping overhead (which
    // reaches ~250 ns in debug builds) so the ordering is robust in any
    // profile; measured over batches to smooth scheduler noise.
    let mut lat = LatencyModel::zero();
    lat.loopback_write_ns = 5_000;
    lat.remote_write_ns = 20_000;
    let d = RdmaDomain::new(2, 1 << 12, DomainConfig::fast_timed().with_latency(lat));
    let home = d.endpoint(0);
    let remote = d.endpoint(1);
    let a = home.alloc(1);
    let iters = 1_000;

    let t0 = Instant::now();
    for _ in 0..iters {
        home.write(a, 1);
    }
    let local_ns = t0.elapsed().as_nanos() / iters;

    let t0 = Instant::now();
    for _ in 0..iters {
        home.r_write(a, 1); // loopback
    }
    let loop_ns = t0.elapsed().as_nanos() / iters;

    let t0 = Instant::now();
    for _ in 0..iters {
        remote.r_write(a, 1); // wire
    }
    let remote_ns = t0.elapsed().as_nanos() / iters;

    assert!(
        local_ns * 5 < loop_ns,
        "local {local_ns} vs loopback {loop_ns}"
    );
    assert!(loop_ns < remote_ns, "loopback {loop_ns} vs remote {remote_ns}");
}

#[test]
fn congestion_penalty_accumulates_under_parallel_load() {
    let mut lat = LatencyModel::fast();
    lat.nic_capacity = 1;
    lat.congestion_ns_per_op = 500;
    let cfg = DomainConfig {
        latency: lat,
        time_mode: TimeMode::Timed,
        atomicity: AtomicityMode::NicSerialized,
        hazard_ns: 0,
        pad_lines: true,
    };
    let d = RdmaDomain::new(3, 1 << 12, cfg);
    let home = d.endpoint(0);
    let a = home.alloc(1);
    let mut ts = vec![];
    for node in 1..3u16 {
        let ep = d.endpoint(node);
        ts.push(std::thread::spawn(move || {
            for _ in 0..500 {
                ep.r_write(a, 7);
            }
        }));
    }
    for t in ts {
        t.join().unwrap();
    }
    let nic = &d.node(0).nic.metrics;
    assert_eq!(
        nic.ops.load(std::sync::atomic::Ordering::Relaxed),
        1000
    );
    // With capacity 1 and two writers, some queueing must be priced in
    // ... on a single-core host overlap is scheduler-dependent, so only
    // require the counter mechanism to be wired (peak depth observed).
    assert!(
        nic.peak_inflight.load(std::sync::atomic::Ordering::Relaxed) >= 1
    );
}

#[test]
fn per_process_metrics_are_isolated_across_shared_domain() {
    let d = RdmaDomain::new(2, 1 << 12, DomainConfig::counted());
    let e1 = d.endpoint(1);
    let e2 = d.endpoint(1);
    let home = d.endpoint(0);
    let a = home.alloc(1);
    e1.r_write(a, 1);
    e1.r_write(a, 2);
    e2.r_read(a);
    assert_eq!(e1.metrics.snapshot().remote_write, 2);
    assert_eq!(e1.metrics.snapshot().remote_read, 0);
    assert_eq!(e2.metrics.snapshot().remote_read, 1);
    assert_eq!(e2.metrics.snapshot().remote_write, 0);
}

#[test]
fn wipe_supports_domain_reuse_between_repetitions() {
    let d = RdmaDomain::new(2, 1 << 12, DomainConfig::counted());
    let home = d.endpoint(0);
    let a = home.alloc(4);
    for i in 0..4 {
        home.write(a.offset(i), i as u64 + 1);
    }
    d.wipe();
    for i in 0..4 {
        assert_eq!(home.read(a.offset(i)), 0);
    }
    // Allocation bump survives (addresses remain valid / unique).
    let b = home.alloc(1);
    assert!(b.word() > a.word());
}
