//! Poll-based acquisition: seeded deterministic explorer runs over the
//! real session stack (see `qplock::sim` and TESTING.md — a failing
//! seed is reproducible verbatim with `sim::run_one(&cfg, seed)` and
//! shrinks to a replayable artifact), plus targeted deterministic
//! constructions and one threaded smoke test (the multiplexed runner
//! acceptance sweep).
//!
//! Invariants covered:
//! * the paper's verb asymmetry survives the poll decomposition —
//!   local-class handles issue zero remote verbs under arbitrary
//!   explored schedules, and a *queued* remote waiter's polls are free
//!   of remote verbs no matter how often it is polled (O(1) remote
//!   verbs per acquisition);
//! * cancelling a submitted-but-not-held acquisition leaves the queue
//!   consistent: no handoff is lost, every other waiter still
//!   acquires, and the oracle stays clean;
//! * one session (one OS thread) can drive many in-flight
//!   acquisitions (`run_multiplexed_workload` at the ISSUE acceptance
//!   scale: ≥ 64 simulated processes, ≥ 100 locks, ≤ 4 OS threads).

use std::sync::Arc;

use qplock::coordinator::{run_multiplexed_workload, Cluster, LockService, Workload};
use qplock::locks::{make_lock, AsyncLockHandle, LockHandle, LockPoll};
use qplock::rdma::{DomainConfig, RdmaDomain};
use qplock::sim::{run_one, SchedMode, SimConfig};
use qplock::util::prng::Prng;

const CASES: u64 = 16;

fn seeds() -> impl Iterator<Item = u64> {
    (0..CASES).map(|i| 0xA51C ^ (i * 0x9E3779B9))
}

#[test]
fn prop_local_class_schedules_issue_zero_remote_verbs() {
    // Any explored schedule over local-class sessions — submits,
    // single-step polls, cancels, ready rounds, releases — must leave
    // the NIC untouched: every register the protocol reads or writes
    // lives on the home node. (Formerly a hand-rolled random poll
    // loop; now the sim explorer drives the same invariant through
    // the real HandleCache sessions, deterministically per seed.)
    let cfg = SimConfig {
        procs: 4,
        locks: 3,
        nodes: 1, // one node ⇒ every handle is local-class
        budget: 4,
        lease_ticks: 32,
        ring_capacity: 8,
        max_steps: 300,
        drain_rounds: 3_000,
        crash_prob: 0.0,
        zombie_prob: 0.0,
        max_crashes: 0,
        manual_arm: false,
        executor_steps: false,
        race_detect: false,
        shared: false,
        mode: SchedMode::Uniform,
    };
    for seed in seeds() {
        let out = run_one(&cfg, seed);
        assert!(out.violation.is_none(), "seed {seed}: {:?}", out.violation);
        assert!(out.completed > 0, "seed {seed}: schedule was inert");
        assert_eq!(
            out.local_remote_verbs, 0,
            "seed {seed}: local class used the NIC"
        );
    }
}

#[test]
fn prop_mixed_class_schedules_stay_exclusive() {
    // Explored schedules over sessions of both classes (with cancels
    // in the alphabet): the per-lock oracles stay clean and the drain
    // always converges — no lost handoff under any explored
    // interleaving of submits, polls, cancels, and releases.
    for seed in seeds() {
        let cfg = SimConfig {
            procs: 3 + (seed % 3) as u32,
            locks: 2 + (seed % 2) as u32,
            nodes: 2 + (seed % 2) as u16,
            budget: 1 + (seed % 4),
            lease_ticks: 32,
            ring_capacity: 8,
            max_steps: 300,
            drain_rounds: 3_000,
            crash_prob: 0.0,
            zombie_prob: 0.0,
            max_crashes: 0,
            manual_arm: false,
            executor_steps: false,
            race_detect: false,
            shared: false,
            mode: if seed % 2 == 0 {
                SchedMode::Uniform
            } else {
                SchedMode::Pct { depth: 3 }
            },
        };
        let out = run_one(&cfg, seed);
        assert!(out.violation.is_none(), "seed {seed}: {:?}", out.violation);
        assert!(out.completed > 0, "seed {seed}: schedule was inert");
    }
}

#[test]
fn prop_queued_remote_waiter_polls_cost_no_remote_verbs() {
    // The scalability keystone: once enqueued, a remote-class waiter's
    // poll reads its own node's budget word. A multiplexer can poll a
    // parked waiter any number of times without adding remote verbs —
    // acquisition stays O(1) remote verbs however long the wait.
    for seed in seeds() {
        let mut rng = Prng::seed_from(seed);
        let d = RdmaDomain::new(3, 1 << 14, DomainConfig::counted());
        let lock = make_lock("qplock", &d, 0, 4, 8);
        let mut holder = lock.handle(d.endpoint(1), 0);
        let ep = d.endpoint(2);
        let metrics = Arc::clone(&ep.metrics);
        let mut waiter = lock.handle(ep, 1);
        for cycle in 0..8 {
            holder.lock();
            let w = waiter.as_async().unwrap();
            // Two polls park the waiter deterministically: poll #1's
            // tail CAS observes the holder (fails), poll #2 swaps in
            // and links behind it (WaitBudget).
            assert_eq!(w.poll_lock(), LockPoll::Pending, "seed {seed}");
            assert_eq!(w.poll_lock(), LockPoll::Pending, "seed {seed}");
            assert!(w.is_acquiring(), "seed {seed}: waiter not enqueued");
            let parked = metrics.snapshot();
            let polls = 100 + rng.below(1_900);
            for _ in 0..polls {
                assert_eq!(w.poll_lock(), LockPoll::Pending, "seed {seed}");
            }
            let spin = metrics.snapshot() - parked;
            assert_eq!(
                spin.remote_total(),
                0,
                "seed {seed} cycle {cycle}: {polls} parked polls issued remote verbs"
            );
            holder.unlock();
            loop {
                match waiter.as_async().unwrap().poll_lock() {
                    LockPoll::Held => break,
                    LockPoll::Pending => {}
                    LockPoll::Cancelled => panic!("seed {seed}: not cancelled"),
                    LockPoll::Expired => panic!("seed {seed}: no leases enabled"),
                }
            }
            waiter.unlock();
        }
        // O(1) per acquisition overall: across 8 cycles with thousands
        // of parked polls, the waiter's verb total stays tiny.
        let total = metrics.snapshot();
        let per_acq = total.remote_total() as f64 / 8.0;
        assert!(per_acq <= 8.0, "seed {seed}: {per_acq} remote verbs/acq");
    }
}

#[test]
fn prop_cancelled_waiter_relays_handoff_to_successor() {
    // holder → cancelled-waiter → successor chains of random length:
    // the cancelled waiters drain (accepting and relaying the budget
    // handoff), the successor always acquires, and nothing leaks.
    for seed in seeds() {
        let mut rng = Prng::seed_from(seed);
        let d = RdmaDomain::new(2, 1 << 14, DomainConfig::counted());
        let lock = make_lock("qplock", &d, 0, 8, 1 + rng.below(4));
        let mut holder = lock.handle(d.endpoint(rng.below(2) as u16), 0);
        let k = 1 + rng.below(3) as usize; // waiters to cancel
        holder.lock();
        let mut cancelled = vec![];
        for pid in 0..k {
            let mut h = lock.handle(d.endpoint(rng.below(2) as u16), pid as u32 + 1);
            // Two polls make the waiter queue-visible (or a parked
            // Peterson leader, if it opened the other cohort's queue);
            // Pending is guaranteed both times while the holder holds.
            assert_eq!(h.as_async().unwrap().poll_lock(), LockPoll::Pending, "seed {seed}");
            assert_eq!(h.as_async().unwrap().poll_lock(), LockPoll::Pending, "seed {seed}");
            cancelled.push(h);
        }
        let mut successor = lock.handle(d.endpoint(rng.below(2) as u16), 7);
        assert_eq!(
            successor.as_async().unwrap().poll_lock(),
            LockPoll::Pending,
            "seed {seed}: holder still holds"
        );
        for h in cancelled.iter_mut() {
            let _ = h.as_async().unwrap().cancel_lock();
        }
        holder.unlock();
        // Drain the cancelled waiters and the successor together.
        let mut rounds = 0;
        let mut got_lock = false;
        while !got_lock {
            rounds += 1;
            assert!(rounds < 1_000_000, "seed {seed}: handoff lost");
            for h in cancelled.iter_mut() {
                let _ = h.as_async().unwrap().poll_lock();
            }
            got_lock = successor.as_async().unwrap().poll_lock() == LockPoll::Held;
        }
        successor.unlock();
        // The lock is healthy: a fresh blocking cycle completes.
        holder.lock();
        holder.unlock();
    }
}

#[test]
fn multiplexed_acceptance_64_procs_100_locks_4_threads() {
    // ISSUE acceptance: ≥ 64 simulated processes over ≥ 100 named
    // locks on ≤ 4 OS threads — zero oracle violations and
    // local-class handles reporting exactly 0 remote verbs.
    let cluster = Cluster::new(3, 1 << 20, DomainConfig::counted());
    let svc = Arc::new(LockService::new(&cluster.domain, "qplock", 8));
    let procs = cluster.round_robin_procs(64);
    let wl = Workload::cycles(40).with_locks(100, 0.99).with_seed(0xA511C);
    let r = run_multiplexed_workload(&svc, &procs, &wl, 4);
    assert_eq!(r.violations, 0, "mutual exclusion violated");
    assert_eq!(r.total_acquisitions(), 64 * 40);
    assert_eq!(svc.len(), 100, "table fully pre-registered");
    assert_eq!(
        r.local_class_remote_verbs(),
        0,
        "local-class handles must stay NIC-clean under multiplexing"
    );
    assert!(r.remote_verbs_per_acq() > 0.0, "remote class did work");
    assert_eq!(r.procs.len(), 64);
    for p in &r.procs {
        assert_eq!(p.acquisitions, 40);
        assert!(p.distinct_locks >= 1);
    }
    // Zipf skew visible at the table level.
    assert!(r.hottest_share() > 0.05, "share {}", r.hottest_share());
}
