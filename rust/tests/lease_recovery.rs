//! Lease-based crash recovery: deterministic constructions of the four
//! named protocol points (holding, enqueued, mid-handoff,
//! armed-for-wakeup), the zombie-writeback fence proof, and random
//! crash schedules through the fault-injection harness.
//!
//! Invariants covered (ISSUE 4 acceptance):
//! * **Mutual exclusion across revoke/fence** — per-lock oracles stay
//!   clean under random kills and stalls at every protocol point; a
//!   double grant (sweeper relay racing a zombie's late release) would
//!   surface as a violation.
//! * **Eventual progress for survivors** — every process that is not
//!   killed completes all of its cycles; a crashed holder or waiter
//!   never wedges the processes behind it.
//! * **Fenced late writes** — a revoked epoch's release/poll observes
//!   `LeaseError::Expired`/`LockPoll::Expired` and touches no shared
//!   state; the double-release-after-revoke path errors instead of
//!   panicking or silently succeeding.

use std::sync::Arc;

use qplock::coordinator::{
    run_crash_workload, Cluster, CrashPlan, HandleCache, LockService, Workload,
};
use qplock::locks::{LeaseError, LockPoll};
use qplock::rdma::DomainConfig;

const TICKS: u64 = 50;

/// A 2-node cluster + lease-enabled service; every lock is created
/// explicitly on node 0 so tests control locality.
fn lease_service() -> (Cluster, Arc<LockService>) {
    let cluster = Cluster::new(2, 1 << 18, DomainConfig::counted());
    let svc = Arc::new(
        LockService::new(&cluster.domain, "qplock", 8)
            .with_default_max_procs(8)
            .with_lease_ticks(TICKS),
    );
    (cluster, svc)
}

/// Park a scan-mode pending acquisition (submit + enough polls to
/// enqueue and reach the budget wait).
fn park(sess: &mut HandleCache, name: &str) {
    assert_eq!(sess.submit(name).unwrap(), LockPoll::Pending);
    for _ in 0..3 {
        assert!(sess.poll_all().is_empty(), "{name}: holder still holds");
    }
}

#[test]
fn crashed_holder_is_revoked_and_the_lock_relayed() {
    // Protocol point: HOLDING. A holder dies in its critical section;
    // the sweeper fences its epoch and relays the release, and the
    // waiting survivor acquires. The zombie's late release — and the
    // double release after it — both surface LeaseError::Expired.
    let (cluster, svc) = lease_service();
    svc.create_lock("h", "qplock", 0, 8, 8).unwrap();
    let mut zombie = svc.session(1);
    assert_eq!(zombie.submit("h").unwrap(), LockPoll::Held);
    let mut survivor = svc.session(1);
    park(&mut survivor, "h");

    // The zombie stops renewing; the survivor keeps polling.
    let now = cluster.domain.advance_lease_clock(10 * TICKS);
    assert!(survivor.poll_all().is_empty());
    let stats = svc.sweep_leases(now);
    assert_eq!(stats.fenced, 1, "exactly the dead holder is revoked");
    assert_eq!(stats.relayed, 1, "its release was relayed to the waiter");
    assert_eq!(stats.reaped, 1);
    assert_eq!(stats.recovery_ticks.count(), 1);

    let held = survivor.poll_all();
    assert_eq!(held, vec!["h".to_string()], "survivor owns the lock");

    // Zombie wakes: the late release is a fenced no-op — and releasing
    // again is the same distinct error, not a panic or silent success.
    assert_eq!(zombie.release("h"), Err(LeaseError::Expired));
    assert_eq!(zombie.release("h"), Err(LeaseError::Expired));
    assert_eq!(zombie.take_expired(), vec!["h".to_string()]);

    // The survivor's ownership was never disturbed.
    survivor.release("h").unwrap();

    // A fresh submit acknowledges the revocation and works again.
    assert_eq!(zombie.submit("h").unwrap(), LockPoll::Held);
    zombie.release("h").unwrap();
}

#[test]
fn crashed_enqueued_waiter_becomes_a_pass_through() {
    // Protocol point: ENQUEUED. A queued waiter dies before its
    // handoff arrives. MCS cannot unlink it, so the sweeper fences it,
    // watches its budget word, and relays the handoff on arrival — the
    // waiter behind it still acquires.
    let (cluster, svc) = lease_service();
    svc.create_lock("e", "qplock", 0, 8, 8).unwrap();
    let mut holder = svc.session(1);
    assert_eq!(holder.submit("e").unwrap(), LockPoll::Held);
    let mut dead = svc.session(1);
    park(&mut dead, "e");
    let mut live = svc.session(1);
    park(&mut live, "e");

    // `dead` goes silent; the holder and `live` renew.
    let now = cluster.domain.advance_lease_clock(10 * TICKS);
    holder.renew("e").unwrap();
    assert!(live.poll_all().is_empty());
    let stats = svc.sweep_leases(now);
    assert_eq!(stats.fenced, 1);
    assert_eq!(stats.watching, 1, "no handoff to relay yet");
    assert_eq!(stats.relayed, 0);

    // The holder releases: the handoff lands in the dead slot; the
    // next sweep relays it past the corpse to `live`.
    holder.release("e").unwrap();
    let stats = svc.sweep_leases(cluster.domain.lease_now());
    assert_eq!(stats.relayed, 1);
    let held = live.poll_all();
    assert_eq!(held, vec!["e".to_string()], "handoff relayed past the corpse");
    live.release("e").unwrap();

    // The dead session's own poll observes the revocation.
    assert!(dead.poll_all().is_empty());
    assert_eq!(dead.take_expired(), vec!["e".to_string()]);
    assert_eq!(dead.pending_count(), 0);
}

#[test]
fn crash_mid_handoff_clears_the_abandoned_tail() {
    // Protocol point: MID-HANDOFF. The handoff lands in a waiter's
    // budget word, and the waiter dies before consuming it. The
    // sweeper finds a fenced slot that already owns the lock, has no
    // successor, and resets the cohort tail — the lock is free again.
    let (cluster, svc) = lease_service();
    svc.create_lock("m", "qplock", 0, 8, 8).unwrap();
    let mut holder = svc.session(1);
    assert_eq!(holder.submit("m").unwrap(), LockPoll::Held);
    let mut dead = svc.session(1);
    park(&mut dead, "m");
    assert!(!dead.handoff_arrived("m"));
    holder.release("m").unwrap();
    assert!(dead.handoff_arrived("m"), "budget landed, unconsumed");

    // The waiter dies exactly here — never polls again.
    let now = cluster.domain.advance_lease_clock(10 * TICKS);
    let stats = svc.sweep_leases(now);
    assert_eq!(stats.fenced, 1);
    assert_eq!(stats.released, 1, "abandoned tail reset");
    assert_eq!(stats.relayed, 0, "nobody was waiting behind it");

    // The lock is fully available to a newcomer.
    let mut fresh = svc.session(0);
    assert_eq!(fresh.submit("m").unwrap(), LockPoll::Held);
    fresh.release("m").unwrap();
}

#[test]
fn crashed_armed_waiter_is_not_signalled_and_successor_is() {
    // Protocol point: ARMED. A dead waiter with an armed wakeup
    // registration must not receive the handoff's token (the sweeper
    // clears its registration at fence time); the relayed-to survivor
    // gets its own signal and wakes through its ring.
    let (cluster, svc) = lease_service();
    svc.create_lock("a", "qplock", 0, 8, 8).unwrap();
    let mut holder = svc.session(1);
    assert_eq!(holder.submit("a").unwrap(), LockPoll::Held);

    let mut dead = svc.session(1);
    dead.enable_ready_wakeups(4);
    dead.set_sweep_interval(0);
    dead.set_lease_heartbeat(0); // it will "die": nothing renews it
    assert_eq!(dead.submit("a").unwrap(), LockPoll::Pending);
    while !dead.is_armed("a") {
        assert!(dead.poll_ready().is_empty());
    }

    let mut live = svc.session(1);
    live.enable_ready_wakeups(4);
    live.set_sweep_interval(0);
    live.set_lease_heartbeat(1); // renew every ready round
    assert_eq!(live.submit("a").unwrap(), LockPoll::Pending);
    while !live.is_armed("a") {
        assert!(live.poll_ready().is_empty());
    }

    // Expire the dead waiter (holder and live keep renewing).
    let now = cluster.domain.advance_lease_clock(10 * TICKS);
    holder.renew("a").unwrap();
    assert!(live.poll_ready().is_empty());
    let stats = svc.sweep_leases(now);
    assert_eq!(stats.fenced, 1);
    assert_eq!(stats.watching, 1);

    // The holder's release writes the handoff into the dead slot; its
    // cleared registration means no token is published for the corpse.
    holder.release("a").unwrap();
    let stats = svc.sweep_leases(cluster.domain.lease_now());
    assert_eq!(stats.relayed, 1, "relay reached the armed survivor");

    // The survivor wakes through its own ring token, O(1) polls.
    let polls0 = live.handle_polls();
    let mut held = Vec::new();
    let mut rounds = 0;
    while held.is_empty() {
        held = live.poll_ready();
        rounds += 1;
        assert!(rounds < 100, "survivor's wakeup token never arrived");
    }
    assert_eq!(held, vec!["a".to_string()]);
    assert!(live.handle_polls() - polls0 <= 2, "woke with O(1) polls");
    live.release("a").unwrap();

    // The dead session, were it to wake, observes the revocation
    // through a renewal, and its release errors.
    assert_eq!(dead.renew("a"), Err(LeaseError::Expired));
    assert_eq!(dead.take_expired(), vec!["a".to_string()]);
    assert_eq!(dead.release("a"), Err(LeaseError::Expired));
}

#[test]
fn local_cohort_repair_stays_off_the_nic() {
    // The asymmetry discipline extends to recovery: fencing is CPU-only
    // everywhere, and repairing a local-class cohort (descriptors,
    // victim, tail[LOCAL], the successor's budget — all on the home
    // node, where the sweeper agent runs) must issue zero remote verbs.
    let (cluster, svc) = lease_service();
    svc.create_lock("l", "qplock", 0, 8, 8).unwrap();
    let mut zombie = svc.session(0);
    assert_eq!(zombie.submit("l").unwrap(), LockPoll::Held);
    let mut survivor = svc.session(0);
    park(&mut survivor, "l");
    let now = cluster.domain.advance_lease_clock(10 * TICKS);
    assert!(survivor.poll_all().is_empty());
    let stats = svc.sweep_leases(now);
    assert_eq!(stats.fenced, 1);
    assert_eq!(stats.relayed, 1);
    assert_eq!(survivor.poll_all(), vec!["l".to_string()]);
    survivor.release("l").unwrap();
    for (node, m) in svc.sweeper_metrics().iter().enumerate() {
        assert_eq!(
            m.remote_total(),
            0,
            "node-{node} sweeper used the NIC repairing a local cohort"
        );
    }
}

#[test]
fn submit_on_an_unrepaired_slot_parks_until_the_reap() {
    // A revoked waiter's descriptor is still a queue pass-through until
    // the sweeper finishes the relay; a resubmit in that window must
    // park (Pending) rather than reuse the slot and corrupt the relay.
    let (cluster, svc) = lease_service();
    svc.create_lock("p", "qplock", 0, 8, 8).unwrap();
    let mut holder = svc.session(1);
    assert_eq!(holder.submit("p").unwrap(), LockPoll::Held);
    let mut w = svc.session(1);
    park(&mut w, "p");
    let now = cluster.domain.advance_lease_clock(10 * TICKS);
    holder.renew("p").unwrap();
    let stats = svc.sweep_leases(now);
    assert_eq!(stats.fenced, 1);
    assert_eq!(stats.watching, 1, "repair pending: handoff still owed");
    // The revoked waiter notices and immediately resubmits — but the
    // slot is fenced-unreaped, so the acquisition cannot start yet.
    assert!(w.poll_all().is_empty());
    assert_eq!(w.take_expired(), vec!["p".to_string()]);
    assert_eq!(w.submit("p").unwrap(), LockPoll::Pending);
    for _ in 0..50 {
        assert!(w.poll_all().is_empty(), "parked until the reap");
    }
    // The holder releases; the sweeper relays (tail reset — the corpse
    // had no successor... it *is* the tail) and reaps; the parked
    // resubmit then proceeds and acquires.
    holder.release("p").unwrap();
    let stats = svc.sweep_leases(cluster.domain.lease_now());
    assert_eq!(stats.reaped, 1);
    let mut held = Vec::new();
    let mut rounds = 0;
    while held.is_empty() {
        held = w.poll_all();
        rounds += 1;
        assert!(rounds < 1_000, "resubmit never recovered after the reap");
    }
    assert_eq!(held, vec!["p".to_string()]);
    w.release("p").unwrap();
}

#[test]
fn crashed_session_churn_reclaims_pid_slots_16x_capacity() {
    // ROADMAP open item (pid-slot reclamation): `HandleCache::crash`
    // used to leak its pid leases by design, so crash churn beyond
    // `max_procs` permanently wedged a service on CapacityExhausted.
    // The service now parks crashed slots in its orphan registry and
    // each sweep returns the ones whose descriptors the sweeper has
    // reaped. 16x the capacity in crashing sessions must keep minting.
    let (cluster, svc) = lease_service();
    svc.create_lock("rc", "qplock", 0, 4, 8).unwrap(); // capacity 4
    let mut reclaimed = 0u64;
    for round in 0..64u64 {
        let mut sess = svc.session((round % 2) as u16);
        if round % 2 == 0 {
            // Crash while HOLDING.
            assert_eq!(
                sess.submit("rc").unwrap(),
                LockPoll::Held,
                "round {round}: capacity eroded by earlier crashes"
            );
            sess.crash();
        } else {
            // Crash while ENQUEUED behind a live holder; the holder
            // then releases onto the corpse (the relay shape).
            let mut holder = svc.session(0);
            assert_eq!(holder.submit("rc").unwrap(), LockPoll::Held, "round {round}");
            assert_eq!(sess.submit("rc").unwrap(), LockPoll::Pending);
            let _ = sess.poll_all(); // reach the parked budget wait
            sess.crash();
            holder.release("rc").unwrap();
        }
        // Sweep until the crashed slot quiesces and its pid returns.
        let mut passes = 0;
        while svc.orphaned_slots() > 0 {
            let now = cluster.domain.advance_lease_clock(2 * TICKS);
            reclaimed += svc.sweep_leases(now).pid_reclaimed;
            passes += 1;
            assert!(passes < 64, "round {round}: orphaned slot never reclaimed");
        }
    }
    assert!(
        reclaimed >= 64,
        "every crashed acquisition's slot must come back: {reclaimed}"
    );
    assert_eq!(svc.free_slots("rc"), Some(4), "pool fully restored");
    let mut fresh = svc.session(0);
    fresh.with_lock("rc", || {}).unwrap();
}

#[test]
fn reminted_descriptor_reregisters_cleanly_after_reap() {
    // contract::Monitor regression (ISSUE 8 satellite): a sweeper reap
    // retires a crashed session's descriptor, and the next session
    // minted from the pool re-registers the *same address* for its
    // re-minted lock words. Re-registration must replace the stale
    // entry wholesale — word, silence class, lane history, and the
    // race detector's per-word clocks — not abort on the duplicate or
    // leak the dead lifetime's state into the new one. Crash-churn
    // with the sanitizer on (debug default) and the race detector
    // enabled: re-minted sessions must keep acquiring and the detector
    // must stay silent.
    let (cluster, svc) = lease_service();
    let mon = cluster.domain.contract_monitor();
    mon.enable_race_detect();
    svc.create_lock("rr", "qplock", 0, 2, 8).unwrap(); // capacity 2
    for round in 0..8u64 {
        mon.set_step(round);
        mon.set_actor(Some((round % 2) as u32));
        let mut sess = svc.session((round % 2) as u16);
        assert_eq!(
            sess.submit("rr").unwrap(),
            LockPoll::Held,
            "round {round}: capacity eroded — a re-registration was refused"
        );
        sess.crash();
        mon.end_of_actor_step();
        let mut passes = 0;
        while svc.orphaned_slots() > 0 {
            let now = cluster.domain.advance_lease_clock(2 * TICKS);
            svc.sweep_leases(now);
            passes += 1;
            assert!(passes < 64, "round {round}: orphaned slot never reclaimed");
        }
    }
    assert!(
        mon.take_race().is_none(),
        "stale registration state leaked a race report across lifetimes"
    );
    let mut fresh = svc.session(0);
    fresh.with_lock("rr", || {}).unwrap();
}

#[test]
fn random_crash_schedules_preserve_safety_and_progress() {
    // Property sweep: small fault-injected runs across seeds — mutual
    // exclusion, survivor progress, and complete repair, every time.
    for seed in 0..6u64 {
        let cluster = Cluster::new(3, 1 << 19, DomainConfig::counted());
        let svc = Arc::new(
            LockService::new(&cluster.domain, "qplock", 8)
                .with_default_max_procs(12)
                .with_lease_ticks(200),
        );
        let procs = cluster.round_robin_procs(12);
        let wl = Workload::cycles(6).with_locks(6, 0.9).with_seed(seed);
        let plan = CrashPlan::all_points(0.01, 0.5, 6);
        let r = run_crash_workload(&svc, &procs, &wl, 3, &plan);
        assert_eq!(r.violations, 0, "seed {seed}: double grant");
        assert!(!r.wedged, "seed {seed}: wedged survivors");
        assert!(
            r.completed >= r.survivors as u64 * 6,
            "seed {seed}: a survivor lost cycles ({} completed, {} survivors)",
            r.completed,
            r.survivors
        );
        assert_eq!(
            r.sweep.fenced, r.sweep.reaped,
            "seed {seed}: a revocation was never repaired"
        );
    }
}

#[test]
fn acceptance_64_procs_100_locks_all_four_points() {
    // The E13 quick-scale acceptance run, as a property test: ≥64
    // procs, ≥100 locks, crashes injected at all four named protocol
    // points — zero violations, zero wedged survivors, every revoked
    // epoch repaired, and at least one zombie late write provably
    // fenced.
    let cluster = Cluster::new(3, 1 << 21, DomainConfig::counted());
    let svc = Arc::new(
        LockService::new(&cluster.domain, "qplock", 8)
            .with_default_max_procs(64)
            .with_lease_ticks(400),
    );
    let procs = cluster.round_robin_procs(64);
    let wl = Workload::cycles(12).with_locks(100, 0.9);
    let plan = CrashPlan::all_points(0.003, 0.5, 16);
    let r = run_crash_workload(&svc, &procs, &wl, 4, &plan);
    assert_eq!(r.violations, 0, "double grant across a revoke/fence");
    assert!(!r.wedged, "wedged survivors");
    assert_eq!(r.points_injected(), 4, "kills {:?} zombies {:?}", r.kills, r.zombies);
    assert!(
        r.completed >= r.survivors as u64 * 12,
        "{} completed, {} survivors",
        r.completed,
        r.survivors
    );
    assert_eq!(r.sweep.fenced, r.sweep.reaped, "unrepaired revocations");
    assert!(
        r.fenced_late_writes >= 1,
        "no zombie late write was fenced (lucky: {})",
        r.lucky_zombies
    );
    assert!(r.sweep.recovery_ticks.count() > 0, "recovery latency unmeasured");
    // Crashed-client reclamation: every killed session parked at least
    // one in-flight slot in the orphan registry, and the drain's
    // fenced == reaped convergence means every one was reaped — so
    // every kill must have returned at least one pid slot to its pool.
    let kills: u64 = r.kills.iter().sum();
    assert!(
        r.pid_slots_reclaimed() >= kills,
        "crash churn leaked pid slots: {} kills, {} reclaimed",
        kills,
        r.pid_slots_reclaimed()
    );
}

#[test]
fn batched_lease_heartbeat_stays_nic_silent() {
    // Doorbell-batching satellite: `HandleCache::renew_pending` opens a
    // batch scope over the whole heartbeat pass, but renewals are local
    // writes on the session's own node by design — the scope must stay
    // empty and the pass must ring zero doorbells on either NIC,
    // keeping the "leases are NIC-silent" §Perf entry intact with
    // batching enabled.
    use std::sync::atomic::Ordering::SeqCst;

    let cluster = Cluster::new(2, 1 << 18, DomainConfig::counted().with_batching(true));
    let svc = Arc::new(
        LockService::new(&cluster.domain, "qplock", 8)
            .with_default_max_procs(8)
            .with_lease_ticks(TICKS),
    );
    svc.create_lock("h", "qplock", 0, 8, 8).unwrap();
    let mut holder = svc.session(1);
    assert_eq!(holder.submit("h").unwrap(), LockPoll::Held);
    let mut parked = svc.session(1);
    park(&mut parked, "h");

    let nics = |n: u16| {
        let m = &cluster.domain.node(n).nic.metrics;
        (m.ops.load(SeqCst), m.doorbells.load(SeqCst))
    };
    let before = (nics(0), nics(1));
    parked.renew_pending();
    holder.renew_pending();
    assert_eq!(before, (nics(0), nics(1)), "lease heartbeat touched a NIC");

    holder.release("h").unwrap();
    let held = parked.poll_all();
    assert_eq!(held, vec!["h".to_string()], "handoff survives the batched heartbeat");
    parked.release("h").unwrap();
}
